//! The paper's hardware-friendly non-linear functions (§III-B).
//!
//! * `exp` — Eq. 2: a 5-term Taylor expansion of `e^x` around `a = 0.5`,
//!   evaluated in Horner form (5 multiplies + 5 adds). The `e^a` factor is
//!   folded into the coefficients "prior", exactly as the paper describes.
//!   Valid on `x ∈ [0, 1]`; a power-of-e range-reduction LUT extends it to
//!   the full softmax input range (the hardware unit pairs the polynomial
//!   with a small ROM).
//! * `div` — Eq. 3: `a / b = e^(log a − log b)`, turning the 49-cycle fixed
//!   point divider into log + log + sub + exp (36 cycles).
//! * `log` — binary normalization (`x = m·2^k`, `m ∈ [1,2)`) plus a Taylor
//!   polynomial of `ln` around 1.5 — mul/add only, matching the unit the
//!   div rewrite requires.
//! * `sqrt` — non-restoring integer square root (used by the Squash unit,
//!   which the paper keeps off the PE array).
//!
//! Each function exists twice: an `f32` form (used by the fp32 reference
//! model and as the oracle in tests) and a `Q4.12` fixed-point form (used
//! by the cycle-level simulator datapath).

use super::Q12;

/// Paper Eq. 2 coefficients (Taylor of e^x about a=0.5, e^a **not** yet
/// folded in). `e^x ≈ e^a · (c0 + x(c1 + x(c2 + x(c3 + x(c4 + c5·x)))))`.
pub const EXP_COEFFS: [f32; 6] = [0.60653, 0.60659, 0.30260, 0.10347, 0.02118, 0.00833];

/// e^0.5 — multiplied "prior" into the coefficients by the hardware unit.
pub const E_HALF: f32 = 1.648_721_3;

/// Coefficients with e^a pre-multiplied: the form the PE array evaluates.
pub fn exp_coeffs_folded() -> [f32; 6] {
    let mut c = EXP_COEFFS;
    for v in &mut c {
        *v *= E_HALF;
    }
    c
}

/// Eq. 2 polynomial on the primary interval x ∈ [0, 1]: 5 mul + 5 add.
pub fn exp_poly_f32(x: f32) -> f32 {
    let c = exp_coeffs_folded();
    c[0] + x * (c[1] + x * (c[2] + x * (c[3] + x * (c[4] + x * c[5]))))
}

/// Range-reduced Taylor exponential: `e^x = e^n · P(f)` with `n = ⌊x⌋`,
/// `f = x − n ∈ [0,1)`. `e^n` comes from a 64-entry ROM (n ∈ [−32, 31]).
pub fn exp_taylor_f32(x: f32) -> f32 {
    let n = x.floor();
    let f = x - n;
    let n = (n as i32).clamp(-32, 31);
    exp_poly_f32(f) * exp2i(n)
}

/// e^n for integer n from the modeled ROM.
fn exp2i(n: i32) -> f32 {
    // Hardware: 64-entry 16-bit ROM; here computed once per call — values
    // are exact powers of e to f32 precision, as a ROM would store.
    std::f32::consts::E.powi(n)
}

/// Taylor `ln` about 1.5 on the normalized mantissa m ∈ [1, 2):
/// `ln x = k·ln2 + ln(1.5) + Σ (−1)^{i+1} t^i / (i·1.5^i)`, t = m − 1.5.
pub fn ln_f32(x: f32) -> f32 {
    assert!(x > 0.0, "ln of non-positive value");
    let bits = x.to_bits();
    let exp = ((bits >> 23) & 0xFF) as i32 - 127;
    let m = f32::from_bits((bits & 0x007F_FFFF) | 0x3F80_0000); // m in [1,2)
    let t = m - 1.5;
    // 5-term Taylor about 1.5 (|t| <= 0.5 -> |t/1.5| <= 1/3, err ~ 2e-4).
    const L15: f32 = 0.405_465_1; // ln 1.5
    let t1 = t / 1.5;
    let poly = t1 * (1.0 + t1 * (-0.5 + t1 * (1.0 / 3.0 + t1 * (-0.25 + t1 * 0.2))));
    exp as f32 * std::f32::consts::LN_2 + L15 + poly
}

/// Eq. 3: `a / b = e^(ln a − ln b)`. Requires a, b > 0 (softmax operands
/// and capsule norms are positive by construction).
pub fn div_explog_f32(a: f32, b: f32) -> f32 {
    if a == 0.0 {
        return 0.0;
    }
    exp_taylor_f32(ln_f32(a) - ln_f32(b))
}

// ---------------------------------------------------------------------------
// Fixed-point (Q4.12) forms — the simulator datapath.
// ---------------------------------------------------------------------------

/// Folded Eq. 2 coefficients quantized to Q4.12 (what the ROM holds).
pub fn exp_coeffs_q12() -> [Q12; 6] {
    let c = exp_coeffs_folded();
    [
        Q12::from_f32(c[0]),
        Q12::from_f32(c[1]),
        Q12::from_f32(c[2]),
        Q12::from_f32(c[3]),
        Q12::from_f32(c[4]),
        Q12::from_f32(c[5]),
    ]
}

/// Q4.12 Eq. 2 polynomial on [0, 1): 5 mul + 5 add on the PE array.
pub fn exp_poly_q12(x: Q12) -> Q12 {
    let c = exp_coeffs_q12();
    let mut acc = c[5];
    for i in (0..5).rev() {
        acc = c[i].add(x.mul(acc));
    }
    acc
}

/// Q4.12 range-reduced exponential. Output saturates at the format max
/// (≈ 8) — softmax numerators are pre-shifted by the max logit, so inputs
/// are ≤ 0 and outputs ≤ 1 in the real datapath.
pub fn exp_taylor_q12(x: Q12) -> Q12 {
    let xf = x.to_f32();
    let n = xf.floor() as i32;
    let f = Q12::from_f32(xf - n as f32);
    let poly = exp_poly_q12(f);
    // ROM holds e^n in Q4.12 for n in [-8, 2]; outside, saturate/flush
    // (e^-9 is below the format's resolution step of 2^-12).
    if n >= 3 {
        return Q12::from_raw(i16::MAX);
    }
    if n <= -9 {
        return Q12::ZERO;
    }
    let rom = Q12::from_f32(std::f32::consts::E.powi(n));
    poly.mul(rom)
}

/// Q4.12 `ln` (operand must be positive). Returns Q4.12 (range ±8 covers
/// ln of the representable positive range: ln(8)≈2.08, ln(2^-12)≈−8.3
/// clamps to the format min).
pub fn ln_q12(x: Q12) -> Q12 {
    debug_assert!(x.raw() > 0, "ln_q12 of non-positive");
    let v = ln_f32(x.to_f32()); // normalization is exact in hardware
    Q12::from_f32(v)
}

/// Q4.12 Eq. 3 division.
pub fn div_explog_q12(a: Q12, b: Q12) -> Q12 {
    if a.raw() <= 0 {
        return Q12::ZERO;
    }
    exp_taylor_q12(ln_q12(a).sub(ln_q12(b)))
}

/// `ln` of a wide accumulator holding a Q4.12-scaled sum (e.g. a softmax
/// denominator Σe^x, which can exceed the Q4.12 range). The hardware log
/// unit normalizes mantissa+exponent from the accumulator register
/// directly, so width costs nothing extra.
pub fn ln_acc_q12(acc: i64) -> Q12 {
    debug_assert!(acc > 0, "ln_acc_q12 of non-positive");
    Q12::from_f32(ln_f32(acc as f32 / 4096.0))
}

/// Exact division of a Q4.12 value by a wide Q4.12-scaled accumulator
/// (the baseline divider with the denominator taken from the accumulator
/// register).
pub fn div_exact_acc_q12(a: Q12, acc: i64) -> Q12 {
    if acc <= 0 {
        return Q12::from_raw(i16::MAX);
    }
    let q = ((a.raw() as i64) << 12) / acc;
    Q12::from_raw(q.clamp(i16::MIN as i64, i16::MAX as i64) as i16)
}

/// Eq. 3 division with a wide-accumulator denominator:
/// `a / Σ = e^(ln a − ln Σ)`.
pub fn div_explog_acc_q12(a: Q12, acc: i64) -> Q12 {
    if a.raw() <= 0 {
        return Q12::ZERO;
    }
    exp_taylor_q12(ln_q12(a).sub(ln_acc_q12(acc)))
}

/// Exact fixed-point division (the 49-cycle baseline divider).
pub fn div_exact_q12(a: Q12, b: Q12) -> Q12 {
    if b.raw() == 0 {
        return Q12::from_raw(i16::MAX);
    }
    let num = (a.raw() as i64) << 12;
    let q = num / b.raw() as i64;
    Q12::from_raw(q.clamp(i16::MIN as i64, i16::MAX as i64) as i16)
}

/// Non-restoring integer square root of a 32-bit value (16 iterations —
/// the Squash unit's dedicated sqrt). Input is raw Q8.24 (i.e. a squared
/// Q4.12 sum); output is Q4.12.
pub fn sqrt_q12(acc: i64) -> Q12 {
    if acc <= 0 {
        return Q12::ZERO;
    }
    // sqrt(x * 2^-24) in Q4.12: isqrt(x) has 2^-12 scale already.
    let x = acc.min(u32::MAX as i64) as u64;
    let mut res: u64 = 0;
    let mut bit: u64 = 1 << 30;
    let mut v = x;
    while bit > x {
        bit >>= 2;
    }
    while bit != 0 {
        if v >= res + bit {
            v -= res + bit;
            res = (res >> 1) + bit;
        } else {
            res >>= 1;
        }
        bit >>= 2;
    }
    Q12::from_raw(res.min(i16::MAX as u64) as i16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_poly_matches_paper_window() {
        // Eq. 2 is built for x in [0, 1]; paper claims "without dropping
        // accuracy" — check < 0.2% relative error across the window.
        let mut worst = 0.0f32;
        for i in 0..=100 {
            let x = i as f32 / 100.0;
            let rel = (exp_poly_f32(x) - x.exp()).abs() / x.exp();
            worst = worst.max(rel);
        }
        assert!(worst < 2e-3, "worst rel err {worst}");
    }

    #[test]
    fn exp_taylor_range_reduced() {
        for x in [-8.0f32, -3.2, -1.0, -0.1, 0.0, 0.7, 1.0, 2.5] {
            let rel = (exp_taylor_f32(x) - x.exp()).abs() / x.exp();
            assert!(rel < 3e-3, "x={x} rel={rel}");
        }
    }

    #[test]
    fn ln_accuracy() {
        for x in [0.001f32, 0.1, 0.5, 1.0, 1.49, 2.0, 7.9, 100.0] {
            let err = (ln_f32(x) - x.ln()).abs();
            assert!(err < 2e-3, "x={x} err={err}");
        }
    }

    #[test]
    fn div_explog_matches_division() {
        for (a, b) in [(1.0f32, 3.0f32), (0.25, 0.5), (5.0, 7.0), (2.0, 0.7)] {
            let got = div_explog_f32(a, b);
            let rel = (got - a / b).abs() / (a / b);
            assert!(rel < 5e-3, "{a}/{b} got {got} rel {rel}");
        }
        assert_eq!(div_explog_f32(0.0, 3.0), 0.0);
    }

    #[test]
    fn q12_exp_tracks_f32() {
        for i in -40..=10 {
            let x = i as f32 / 5.0; // [-8, 2]
            let q = exp_taylor_q12(Q12::from_f32(x)).to_f32();
            let want = x.exp();
            if want > 7.9 {
                continue; // saturation region
            }
            assert!(
                (q - want).abs() < 0.01 + want * 0.01,
                "x={x} q={q} want={want}"
            );
        }
    }

    #[test]
    fn q12_div_tracks_exact_on_softmax_range() {
        // Softmax divides e^b (in (0,1]) by a sum in (0, 10].
        for (a, b) in [(0.3f32, 1.7f32), (1.0, 4.2), (0.05, 0.9), (0.9, 1.0)] {
            let qa = Q12::from_f32(a);
            let qb = Q12::from_f32(b);
            let approx = div_explog_q12(qa, qb).to_f32();
            let exact = div_exact_q12(qa, qb).to_f32();
            assert!(
                (approx - exact).abs() < 0.01,
                "{a}/{b}: approx {approx} exact {exact}"
            );
        }
    }

    #[test]
    fn exact_divider_is_exact() {
        let a = Q12::from_f32(3.0);
        let b = Q12::from_f32(1.5);
        assert_eq!(div_exact_q12(a, b).to_f32(), 2.0);
        assert_eq!(div_exact_q12(a, Q12::ZERO).raw(), i16::MAX);
    }

    #[test]
    fn sqrt_known_values() {
        // ‖s‖² accumulators are Q8.24: value v -> raw v·2^24.
        for v in [0.0f64, 0.25, 1.0, 2.0, 4.0, 16.0, 60.0] {
            let acc = (v * (1u64 << 24) as f64) as i64;
            let got = sqrt_q12(acc).to_f32() as f64;
            assert!(
                (got - v.sqrt()).abs() < 2e-3 + v.sqrt() * 1e-3,
                "sqrt({v}) got {got}"
            );
        }
    }

    #[test]
    fn exp_q12_saturates() {
        assert_eq!(exp_taylor_q12(Q12::from_f32(5.0)).raw(), i16::MAX);
        // At the format's negative extreme, e^x ≈ e^-8 = 3.4e-4 — within
        // one resolution step of zero (raw 0 or 1).
        assert!(exp_taylor_q12(Q12::from_f32(-7.99)).raw() <= 1);
    }
}
