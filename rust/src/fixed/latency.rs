//! Clock-cycle cost of every datapath operation, for the baseline
//! (Vivado HLS default) and optimized (§III-B) implementations.
//!
//! The paper's numbers (100 MHz target on the Zynq-7020):
//!
//! | op            | baseline | optimized | paper source                   |
//! |---------------|----------|-----------|--------------------------------|
//! | `exp`         | 27       | 14        | §III-B: "27 cycles to 14"      |
//! | fixed `div`   | 49       | 36        | §III-B: "49 cycles to 36"      |
//! | `log`         | —        | 11        | component of Eq. 3 (2·11+14=36)|
//! | 16-bit mul    | 3        | 3         | DSP48E pipelined multiply      |
//! | add/sub       | 1        | 1         | fabric adder                   |
//! | `sqrt`        | 16       | 16        | 16-iteration non-restoring     |
//! | BRAM rd/wr    | 1        | 1         | dual-port, 1 access/port/cycle |
//!
//! The div rewrite (Eq. 3) is `2·log + exp = 2·11 + 14 = 36` — the
//! subtraction fuses into the exp pipeline's first stage, which is how the
//! paper reaches exactly 36.

/// A datapath operation with a modeled cycle cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    Add,
    Mul,
    /// One pipelined multiply-accumulate slot (II=1 once the pipe is full).
    Mac,
    /// Baseline CORDIC-style exponential.
    ExpFull,
    /// Eq. 2 Taylor exponential (5 mul + 5 add + ROM, pipelined).
    ExpTaylor,
    /// Baseline fixed-point divider.
    DivFixed,
    /// Eq. 3 divider: exp(log a − log b).
    DivExpLog,
    /// Normalization + Taylor log (component of DivExpLog).
    Log,
    /// Non-restoring square root (Squash unit).
    Sqrt,
    BramRead,
    BramWrite,
}

impl Op {
    /// Latency in clock cycles of a single (unpipelined) evaluation.
    pub fn cycles(self) -> u64 {
        match self {
            Op::Add => 1,
            Op::Mul => 3,
            Op::Mac => 1,
            Op::ExpFull => 27,
            Op::ExpTaylor => 14,
            Op::DivFixed => 49,
            Op::DivExpLog => 36,
            Op::Log => 11,
            Op::Sqrt => 16,
            Op::BramRead => 1,
            Op::BramWrite => 1,
        }
    }

    /// Initiation interval when the op is instantiated as a pipelined unit
    /// (how often a new input can be issued). Iterative units (divider,
    /// sqrt, baseline exp) do not pipeline in the paper's design.
    pub fn initiation_interval(self) -> u64 {
        match self {
            Op::Add | Op::Mul | Op::Mac | Op::BramRead | Op::BramWrite => 1,
            Op::ExpTaylor => 1, // PE-array polynomial: fully pipelined
            Op::Log => 1,
            Op::DivExpLog => 1, // composed of pipelined log/exp stages
            Op::ExpFull => Op::ExpFull.cycles(),
            Op::DivFixed => Op::DivFixed.cycles(),
            Op::Sqrt => Op::Sqrt.cycles(),
        }
    }

    /// DSP48E slices one instance of the unit consumes (resource model).
    pub fn dsp_cost(self) -> u32 {
        match self {
            Op::Mul | Op::Mac => 1,
            Op::ExpTaylor => 5, // 5 Horner multiplies mapped to DSPs
            Op::ExpFull => 4,
            Op::DivFixed => 0, // LUT-based iterative divider
            Op::DivExpLog => 7, // 2 log units (1 DSP each) + exp (5)
            Op::Log => 1,
            Op::Sqrt => 0,
            _ => 0,
        }
    }
}

/// Cycles to stream `n` independent evaluations through one unit
/// (pipeline fill + II-spaced issues).
pub fn pipelined_cycles(op: Op, n: u64) -> u64 {
    if n == 0 {
        return 0;
    }
    op.cycles() + (n - 1) * op.initiation_interval()
}

/// Cycles for `n` evaluations spread across `units` parallel instances.
pub fn parallel_cycles(op: Op, n: u64, units: u64) -> u64 {
    if n == 0 || units == 0 {
        return 0;
    }
    let per_unit = n.div_ceil(units);
    pipelined_cycles(op, per_unit)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_headline_latencies() {
        assert_eq!(Op::ExpFull.cycles(), 27);
        assert_eq!(Op::ExpTaylor.cycles(), 14);
        assert_eq!(Op::DivFixed.cycles(), 49);
        assert_eq!(Op::DivExpLog.cycles(), 36);
        // Eq. 3 composition: 2·log + exp = 36.
        assert_eq!(2 * Op::Log.cycles() + Op::ExpTaylor.cycles(), 36);
    }

    #[test]
    fn pipelining_amortizes() {
        // 100 Taylor exps through one pipelined unit: 14 + 99 ≈ 1.13 c/op.
        assert_eq!(pipelined_cycles(Op::ExpTaylor, 100), 113);
        // Baseline exp cannot pipeline: 100 * 27.
        assert_eq!(pipelined_cycles(Op::ExpFull, 100), 27 * 100);
    }

    #[test]
    fn parallel_splits_work() {
        assert_eq!(parallel_cycles(Op::Mac, 1000, 10), 1 + 99);
        assert_eq!(parallel_cycles(Op::Mac, 0, 10), 0);
        assert_eq!(parallel_cycles(Op::Mac, 5, 10), 1);
    }

    #[test]
    fn optimized_always_at_least_as_fast() {
        assert!(Op::ExpTaylor.cycles() < Op::ExpFull.cycles());
        assert!(Op::DivExpLog.cycles() < Op::DivFixed.cycles());
        assert!(Op::ExpTaylor.initiation_interval() <= Op::ExpFull.initiation_interval());
    }
}
