//! 16-bit fixed-point arithmetic — the accelerator's numeric substrate.
//!
//! The paper quantizes all CapsNet parameters to 16 bits (§IV-B) and
//! executes the datapath on DSP48E slices. We model that with saturating
//! Q-format arithmetic:
//!
//! * `Fx<8>`  (Q8.8)  — convolution weights/activations (range ±128).
//! * `Fx<12>` (Q4.12) — capsule vectors, routing logits and coupling
//!   coefficients (range ±8, resolution 2.4e-4; capsule lengths are ≤ 1 by
//!   construction so the extra fractional bits buy softmax head-room).
//!
//! The non-linear units the paper optimizes (`exp`, `div`, `log`, `sqrt`)
//! live in [`taylor`]; per-op clock-cycle costs in [`latency`]. Keeping
//! value computation and cycle cost in one module family guarantees the
//! simulator's timing and numerics can never diverge.

pub mod latency;
pub mod taylor;

/// Saturating 16-bit fixed-point number with `F` fractional bits.
/// `repr(transparent)` over its raw i16 so slices of `Fx` can be viewed
/// as raw bit slices for the SIMD kernels ([`raw_slice`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
#[repr(transparent)]
pub struct Fx<const F: u32>(pub i16);

/// View a Q-format slice as its raw i16 values (sound because `Fx` is
/// `repr(transparent)` over `i16`).
#[inline]
pub fn raw_slice<const F: u32>(xs: &[Fx<F>]) -> &[i16] {
    // SAFETY: `Fx<F>` is `#[repr(transparent)]` over `i16`, so the cast
    // preserves layout; length and lifetime come from the same slice.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const i16, xs.len()) }
}

/// Mutable raw view of a Q-format slice (see [`raw_slice`]).
#[inline]
pub fn raw_slice_mut<const F: u32>(xs: &mut [Fx<F>]) -> &mut [i16] {
    // SAFETY: as in `raw_slice`; the `&mut` borrow guarantees the view
    // is exclusive for its lifetime.
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr() as *mut i16, xs.len()) }
}

/// Main conv datapath format (Q8.8).
pub type Q8 = Fx<8>;
/// Capsule / routing datapath format (Q4.12).
pub type Q12 = Fx<12>;

fn sat16(v: i32) -> i16 {
    v.clamp(i16::MIN as i32, i16::MAX as i32) as i16
}

// `add`/`mul`/... deliberately shadow the operator names instead of
// implementing `std::ops`: every call site should read as *saturating
// Q-format* arithmetic, not ordinary `+`/`*` — the visible method name
// is the reminder that these ops round and clamp like the DSP48E path.
#[allow(clippy::should_implement_trait)]
impl<const F: u32> Fx<F> {
    pub const FRAC: u32 = F;
    pub const ONE: Fx<F> = Fx(1 << F);
    pub const ZERO: Fx<F> = Fx(0);

    /// Quantize an f32 (round-to-nearest, saturate).
    pub fn from_f32(v: f32) -> Fx<F> {
        let scaled = (v * (1i32 << F) as f32).round();
        if scaled >= i16::MAX as f32 {
            Fx(i16::MAX)
        } else if scaled <= i16::MIN as f32 {
            Fx(i16::MIN)
        } else {
            Fx(scaled as i16)
        }
    }

    pub fn to_f32(self) -> f32 {
        self.0 as f32 / (1i32 << F) as f32
    }

    pub fn raw(self) -> i16 {
        self.0
    }

    pub fn from_raw(raw: i16) -> Fx<F> {
        Fx(raw)
    }

    /// Saturating addition.
    pub fn add(self, rhs: Fx<F>) -> Fx<F> {
        Fx(sat16(self.0 as i32 + rhs.0 as i32))
    }

    /// Saturating subtraction.
    pub fn sub(self, rhs: Fx<F>) -> Fx<F> {
        Fx(sat16(self.0 as i32 - rhs.0 as i32))
    }

    /// Saturating multiplication (i32 intermediate, round-to-nearest —
    /// matches a DSP48E multiply + rounding shift).
    pub fn mul(self, rhs: Fx<F>) -> Fx<F> {
        let prod = self.0 as i32 * rhs.0 as i32;
        let rounded = (prod + (1 << (F - 1))) >> F;
        Fx(sat16(rounded))
    }

    /// Multiply–accumulate into a wide accumulator (raw Q2F product).
    /// Hardware keeps the accumulator in the DSP's 48-bit register; we use
    /// i64 to preserve that "never overflows mid-sum" property.
    pub fn mac(self, rhs: Fx<F>, acc: i64) -> i64 {
        acc + (self.0 as i64) * (rhs.0 as i64)
    }

    /// Collapse a wide accumulator back to Q-format (round + saturate).
    pub fn from_acc(acc: i64) -> Fx<F> {
        let rounded = (acc + (1 << (F - 1))) >> F;
        Fx(sat16(rounded.clamp(i32::MIN as i64, i32::MAX as i64) as i32))
    }

    pub fn neg(self) -> Fx<F> {
        Fx(sat16(-(self.0 as i32)))
    }

    pub fn abs(self) -> Fx<F> {
        Fx(sat16((self.0 as i32).abs()))
    }

    pub fn max(self, rhs: Fx<F>) -> Fx<F> {
        if self.0 >= rhs.0 {
            self
        } else {
            rhs
        }
    }

    /// Convert between Q-formats (shift with rounding, saturate).
    pub fn convert<const G: u32>(self) -> Fx<G> {
        let v = self.0 as i32;
        let out = if G >= F {
            v << (G - F)
        } else {
            let sh = F - G;
            (v + (1 << (sh - 1))) >> sh
        };
        Fx::<G>(sat16(out))
    }
}

/// Quantize an f32 slice into Q-format raw values.
pub fn quantize_slice<const F: u32>(xs: &[f32]) -> Vec<Fx<F>> {
    xs.iter().map(|&x| Fx::<F>::from_f32(x)).collect()
}

/// Worst-case absolute quantization error of the format.
pub fn quantization_step<const F: u32>() -> f32 {
    1.0 / (1i32 << F) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_small_values() {
        for v in [-3.5f32, -0.25, 0.0, 0.004, 1.0, 7.96875] {
            let q = Q12::from_f32(v);
            assert!(
                (q.to_f32() - v).abs() <= quantization_step::<12>(),
                "v={v} got {}",
                q.to_f32()
            );
        }
    }

    #[test]
    fn saturation() {
        assert_eq!(Q8::from_f32(1000.0).raw(), i16::MAX);
        assert_eq!(Q8::from_f32(-1000.0).raw(), i16::MIN);
        let big = Q8::from_f32(127.0);
        assert_eq!(big.add(big).raw(), i16::MAX);
        assert_eq!(big.neg().add(big.neg()).raw(), i16::MIN);
    }

    #[test]
    fn mul_known_values() {
        let a = Q8::from_f32(2.5);
        let b = Q8::from_f32(-4.0);
        assert_eq!(a.mul(b).to_f32(), -10.0);
        let one = Q8::ONE;
        assert_eq!(one.mul(one), one);
    }

    #[test]
    fn mul_rounds_to_nearest() {
        // 0.5 * (1/256) = 1/512 rounds to 1/256 (ties toward +inf after shift).
        let a = Q8::from_f32(0.5);
        let eps = Q8::from_raw(1);
        assert_eq!(a.mul(eps).raw(), 1);
    }

    #[test]
    fn mac_accumulates_wide() {
        let a = Q12::from_f32(7.9);
        let mut acc = 0i64;
        for _ in 0..1000 {
            acc = a.mac(a, acc); // 1000 * 62.4 ≈ 62410 — overflows Q4.12
        }
        // Accumulator holds it; collapse saturates.
        assert_eq!(Q12::from_acc(acc).raw(), i16::MAX);
        // A short sum stays exact.
        let b = Q12::from_f32(0.5);
        let acc2 = b.mac(b, b.mac(b, 0));
        assert_eq!(Q12::from_acc(acc2).to_f32(), 0.5);
    }

    #[test]
    fn format_conversion() {
        let a = Q8::from_f32(1.5);
        let b: Q12 = a.convert();
        assert_eq!(b.to_f32(), 1.5);
        let c = Q12::from_f32(7.999);
        let d: Q8 = c.convert();
        assert!((d.to_f32() - 7.999).abs() <= quantization_step::<8>());
        // Saturating down-range conversion: Q8 127 exceeds Q12's ±8.
        let big = Q8::from_f32(100.0);
        let e: Q12 = big.convert();
        assert_eq!(e.raw(), i16::MAX);
    }

    #[test]
    fn quantize_slice_len() {
        let v = quantize_slice::<8>(&[0.1, 0.2, 0.3]);
        assert_eq!(v.len(), 3);
    }
}
