//! Dense f32 tensor with the small op set the CapsNet reference model and
//! the pruning engines need: shaped storage, indexing, matmul, 2-D
//! convolution (NCHW · OIHW), reductions and element-wise maps.
//!
//! This is the *functional* (fp32) substrate; the quantized, cycle-counted
//! datapath lives in [`crate::fixed`] and [`crate::fpga`].

use anyhow::{bail, Result};

/// Row-major dense f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; n],
        }
    }

    /// He-normal initialisation (for the fp32 reference model / tests).
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::util::rng::Rng) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal_f32(0.0, std)).collect();
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, &ix) in idx.iter().enumerate() {
            debug_assert!(ix < self.shape[i], "index {idx:?} out of {:?}", self.shape);
            off = off * self.shape[i] + ix;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let off = self.offset(idx);
        self.data[off] = v;
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn relu(&self) -> Tensor {
        self.map(|x| x.max(0.0))
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("add shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        })
    }

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn abs_sum(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).sum()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn argmax(&self) -> usize {
        crate::util::argmax(&self.data)
    }

    /// `[m,k] x [k,n] -> [m,n]` matrix multiply.
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.shape[1] != other.shape[0] {
            bail!(
                "matmul shape mismatch {:?} x {:?}",
                self.shape,
                other.shape
            );
        }
        let (m, k) = (self.shape[0], self.shape[1]);
        let n = other.shape[1];
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let row = &other.data[p * n..(p + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                crate::kernels::axpy_f32(orow, a, row);
            }
        }
        Tensor::from_vec(&[m, n], out)
    }
}

/// 2-D convolution: input `[C_in, H, W]`, weight `[C_out, C_in, KH, KW]`,
/// bias `[C_out]`, valid padding, square stride. Output `[C_out, H', W']`.
pub fn conv2d(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&Tensor>,
    stride: usize,
) -> Result<Tensor> {
    if input.rank() != 3 || weight.rank() != 4 {
        bail!(
            "conv2d wants [C,H,W] x [O,I,KH,KW], got {:?} x {:?}",
            input.shape,
            weight.shape
        );
    }
    let (c_in, h, w) = (input.shape[0], input.shape[1], input.shape[2]);
    let (c_out, c_in_w, kh, kw) =
        (weight.shape[0], weight.shape[1], weight.shape[2], weight.shape[3]);
    if c_in != c_in_w {
        bail!("conv2d channel mismatch {} vs {}", c_in, c_in_w);
    }
    if h < kh || w < kw {
        bail!("conv2d kernel larger than input");
    }
    let oh = (h - kh) / stride + 1;
    let ow = (w - kw) / stride + 1;
    let mut out = Tensor::zeros(&[c_out, oh, ow]);
    // Tap-outer nest: bias seeds the whole output plane, then every
    // weight tap contributes one strided axpy over an output row
    // (dispatched into the SIMD kernel layer). Per output element the
    // f32 adds still arrive in (i, ky, kx) order — the same rounded
    // multiply/add sequence as the classic position-major nest, so the
    // restructure changes no bits (and the sparse-compiled layer's
    // masked-dense bit-equality contract keeps holding).
    for o in 0..c_out {
        let b = bias.map(|t| t.data[o]).unwrap_or(0.0);
        let plane = &mut out.data[o * oh * ow..][..oh * ow];
        plane.fill(b);
        for i in 0..c_in {
            for ky in 0..kh {
                let w_row = &weight.data[((o * c_in + i) * kh + ky) * kw..][..kw];
                for oy in 0..oh {
                    let iy = oy * stride + ky;
                    let in_row = &input.data[(i * h + iy) * w..][..w];
                    let out_row = &mut plane[oy * ow..][..ow];
                    for (kx, &wv) in w_row.iter().enumerate() {
                        crate::kernels::axpy_strided_f32(out_row, wv, &in_row[kx..], stride);
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Number of multiply–accumulate operations a conv layer performs.
pub fn conv2d_macs(c_in: usize, c_out: usize, oh: usize, ow: usize, kh: usize, kw: usize) -> u64 {
    (c_out * oh * ow) as u64 * (c_in * kh * kw) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn indexing_round_trip() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set(&[1, 2, 3], 7.5);
        assert_eq!(t.at(&[1, 2, 3]), 7.5);
        assert_eq!(t.offset(&[1, 2, 3]), 12 + 2 * 4 + 3);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let id = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        assert_eq!(a.matmul(&id).unwrap(), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Tensor::from_vec(&[3, 2], vec![7., 8., 9., 10., 11., 12.]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn conv2d_known_values() {
        // 1x3x3 input, 1x1x2x2 kernel of ones, stride 1 -> 2x2 sums.
        let input =
            Tensor::from_vec(&[1, 3, 3], (1..=9).map(|x| x as f32).collect()).unwrap();
        let w = Tensor::full(&[1, 1, 2, 2], 1.0);
        let out = conv2d(&input, &w, None, 1).unwrap();
        assert_eq!(out.shape, vec![1, 2, 2]);
        assert_eq!(out.data, vec![12.0, 16.0, 24.0, 28.0]);
    }

    #[test]
    fn conv2d_stride_and_bias() {
        let input = Tensor::full(&[2, 5, 5], 1.0);
        let w = Tensor::full(&[3, 2, 3, 3], 0.5);
        let bias = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let out = conv2d(&input, &w, Some(&bias), 2).unwrap();
        assert_eq!(out.shape, vec![3, 2, 2]);
        // Each output: 2*3*3 taps * 0.5 + bias = 9 + bias.
        assert_eq!(out.at(&[0, 0, 0]), 10.0);
        assert_eq!(out.at(&[1, 1, 1]), 11.0);
        assert_eq!(out.at(&[2, 0, 1]), 12.0);
    }

    #[test]
    fn conv2d_rejects_mismatch() {
        let input = Tensor::zeros(&[2, 5, 5]);
        let w = Tensor::zeros(&[3, 4, 3, 3]);
        assert!(conv2d(&input, &w, None, 1).is_err());
    }

    #[test]
    fn macs_formula() {
        // Conv1 of CapsNet-MNIST: 1->256 ch, 9x9 kernel, 20x20 out.
        assert_eq!(conv2d_macs(1, 256, 20, 20, 9, 9), 8_294_400);
    }

    #[test]
    fn randn_distribution() {
        let mut rng = Rng::new(3);
        let t = Tensor::randn(&[64, 64], 0.1, &mut rng);
        let m = t.sum() / t.len() as f32;
        assert!(m.abs() < 0.01);
    }

    #[test]
    fn reshape_checks_size() {
        let t = Tensor::zeros(&[4, 4]);
        assert!(t.clone().reshape(&[2, 8]).is_ok());
        assert!(t.reshape(&[3, 5]).is_err());
    }
}
