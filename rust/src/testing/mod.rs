//! Property-based testing helpers (proptest is not in the vendored crate
//! set). [`check`] runs a property over `n` generated cases from a seeded
//! [`Rng`], reporting the failing case index and seed on failure so runs
//! are reproducible.

use crate::util::rng::Rng;

/// Run `prop` over `cases` inputs drawn from `gen`. Panics with the seed
/// and case index on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): input = {input:?}"
            );
        }
    }
}

/// Like [`check`] but the property returns `Result` with a message.
pub fn check_msg<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {msg}\ninput = {input:?}"
            );
        }
    }
}

/// Heap-allocation-counting wrapper around the system allocator, for
/// steady-state "this path must not allocate" regression tests
/// (`tests/alloc_regression.rs` registers it as the `#[global_allocator]`
/// of that test binary only — the library never installs it).
pub struct CountingAllocator {
    inner: std::alloc::System,
    allocs: std::sync::atomic::AtomicU64,
}

impl CountingAllocator {
    pub const fn new() -> CountingAllocator {
        CountingAllocator {
            inner: std::alloc::System,
            allocs: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Number of allocation calls (`alloc` + growing `realloc`) so far.
    pub fn allocations(&self) -> u64 {
        self.allocs.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl Default for CountingAllocator {
    fn default() -> CountingAllocator {
        CountingAllocator::new()
    }
}

// SAFETY: delegates verbatim to `std::alloc::System`; the counter is a
// relaxed atomic with no allocation of its own.
unsafe impl std::alloc::GlobalAlloc for CountingAllocator {
    // SAFETY: the caller upholds `GlobalAlloc::alloc`'s contract.
    unsafe fn alloc(&self, layout: std::alloc::Layout) -> *mut u8 {
        self.allocs
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // SAFETY: `layout` is forwarded unchanged to the delegate.
        unsafe { std::alloc::GlobalAlloc::alloc(&self.inner, layout) }
    }

    // SAFETY: the caller upholds `GlobalAlloc::dealloc`'s contract.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: std::alloc::Layout) {
        // SAFETY: `ptr` was produced by `self.inner` (every allocation
        // path delegates to it), so returning it unchanged is sound.
        unsafe { std::alloc::GlobalAlloc::dealloc(&self.inner, ptr, layout) }
    }

    // SAFETY: the caller upholds `GlobalAlloc::realloc`'s contract.
    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: std::alloc::Layout,
        new_size: usize,
    ) -> *mut u8 {
        self.allocs
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // SAFETY: `ptr` came from `self.inner`; arguments forwarded
        // unchanged to the delegate.
        unsafe { std::alloc::GlobalAlloc::realloc(&self.inner, ptr, layout, new_size) }
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "{ctx}: element {i} differs: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 100, 1, |r| (r.f32(), r.f32()), |(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics() {
        check("always-false", 10, 2, |r| r.f32(), |_| false);
    }

    #[test]
    fn allclose_tolerances() {
        assert_allclose(&[1.0, 2.0], &[1.0001, 2.0], 1e-3, 0.0, "ok");
    }

    #[test]
    #[should_panic(expected = "element 1 differs")]
    fn allclose_catches_mismatch() {
        assert_allclose(&[1.0, 2.0], &[1.0, 3.0], 1e-3, 0.0, "bad");
    }
}
