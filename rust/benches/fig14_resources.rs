//! Bench: Fig. 14 — resource utilization of the pruned CapsNet,
//! non-optimized vs optimized, plus the BRAM allocation plan detail.

use fastcaps::config::SystemConfig;
use fastcaps::fpga::resources;
use fastcaps::util::bench::{report_model, Bencher};

fn main() {
    let mut b = Bencher::new();
    b.section("Fig. 14 — modeled resources (pruned MNIST)");
    for (name, cfg) in [
        ("non-optimized", SystemConfig::pruned("mnist")),
        ("optimized", SystemConfig::proposed("mnist")),
    ] {
        let u = resources::estimate(&cfg);
        report_model(&format!("{name} LUT"), u.luts as f64, "LUTs");
        report_model(&format!("{name} LUTRAM"), u.lutram as f64, "LUTs");
        report_model(&format!("{name} BRAM"), u.bram36 as f64, "BRAM36");
        report_model(&format!("{name} DSP"), u.dsp48e as f64, "DSP48E");
    }

    b.section("BRAM plan detail (proposed MNIST)");
    let plan = resources::bram_plan(&SystemConfig::proposed("mnist"));
    let mut grouped: std::collections::BTreeMap<String, f32> = Default::default();
    for buf in &plan.buffers {
        let key = buf.name.split(".bank").next().unwrap_or(&buf.name).to_string();
        *grouped.entry(key).or_default() += buf.blocks;
    }
    for (name, blocks) in grouped {
        report_model(&format!("bram.{name}"), blocks as f64, "BRAM36");
    }
    report_model("bram.total", plan.total_blocks() as f64, "BRAM36");

    b.section("host cost");
    b.bench("resource estimate (both configs)", || {
        let a = resources::estimate(&SystemConfig::pruned("mnist"));
        let c = resources::estimate(&SystemConfig::proposed("mnist"));
        a.luts + c.luts
    });
}
