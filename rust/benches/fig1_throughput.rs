//! Bench: Fig. 1 — throughput and energy across all six configurations
//! (original / pruned / pruned+optimized × MNIST / F-MNIST), plus the
//! frame-pipelined steady-state throughput of each (frames stream
//! through the stage sequence at the slowest stage's initiation
//! interval — the sustained-serving number).

use fastcaps::config::SystemConfig;
use fastcaps::fpga::{power::PowerModel, resources, DeployedModel};
use fastcaps::util::bench::{report_model, Bencher};

fn main() {
    let mut b = Bencher::new();
    let pm = PowerModel::default();
    b.section("Fig. 1 — modeled FPS / FPJ (paper: 5→82→1351 MNIST, 48→934 F-MNIST)");
    for (name, cfg) in [
        ("original-mnist", SystemConfig::original("mnist")),
        ("pruned-mnist", SystemConfig::pruned("mnist")),
        ("proposed-mnist", SystemConfig::proposed("mnist")),
        ("original-fmnist", SystemConfig::original("fmnist")),
        ("pruned-fmnist", SystemConfig::pruned("fmnist")),
        ("proposed-fmnist", SystemConfig::proposed("fmnist")),
    ] {
        let model = DeployedModel::timing_stub(&cfg, 7);
        let t = model.estimate_frame();
        let bt = model.estimate_batch(8);
        let u = resources::estimate(&cfg);
        report_model(&format!("{name} FPS (single frame)"), t.fps(), "frames/s");
        report_model(
            &format!("{name} FPS (pipelined steady-state)"),
            bt.steady_state_fps(),
            "frames/s",
        );
        report_model(
            &format!("{name} FPJ"),
            pm.fpj(t.fps(), &u, !cfg.is_pruned()),
            "frames/J",
        );
    }

    b.section("host cost of the full Fig. 1 sweep");
    b.bench("all six configs, estimate + resources + power", || {
        let mut acc = 0.0;
        for cfg in [
            SystemConfig::original("mnist"),
            SystemConfig::pruned("mnist"),
            SystemConfig::proposed("mnist"),
            SystemConfig::original("fmnist"),
            SystemConfig::pruned("fmnist"),
            SystemConfig::proposed("fmnist"),
        ] {
            let model = DeployedModel::timing_stub(&cfg, 7);
            let t = model.estimate_frame();
            let u = resources::estimate(&cfg);
            acc += pm.fpj(t.fps(), &u, !cfg.is_pruned());
        }
        acc
    });
}
