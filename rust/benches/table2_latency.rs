//! Bench: Table II — single-frame latency, original vs proposed (MNIST).
//!
//! Reports the *modeled* FPGA latency (the paper's number: 0.19 s vs
//! 0.74 ms) and measures the *host* cost of the simulator itself (both
//! the timing-only estimate and the full functional frame), guarding the
//! simulator against performance regressions.

use fastcaps::config::SystemConfig;
use fastcaps::data::{generate, Task};
use fastcaps::fpga::DeployedModel;
use fastcaps::util::bench::{report_model, Bencher};

fn main() {
    let mut b = Bencher::new();
    b.section("Table II — modeled single-frame latency");
    for (name, cfg, paper_s) in [
        ("original-mnist", SystemConfig::original("mnist"), 0.19),
        ("proposed-mnist", SystemConfig::proposed("mnist"), 0.00074),
    ] {
        let model = DeployedModel::timing_stub(&cfg, 7);
        let t = model.estimate_frame();
        report_model(
            &format!("{name} modeled latency (paper {paper_s}s)"),
            t.latency_s(),
            "s/frame",
        );
        report_model(&format!("{name} modeled throughput"), t.fps(), "FPS");
    }

    b.section("host cost of the simulator (regression guard)");
    let proposed = DeployedModel::timing_stub(&SystemConfig::proposed("mnist"), 7);
    b.bench("estimate_frame (timing only)", || {
        proposed.estimate_frame().total_cycles()
    });
    let img = generate(Task::Digits, 1, 3).images.remove(0);
    b.bench("run_frame proposed (functional Q-format)", || {
        proposed.run_frame(&img).unwrap().0
    });
    let original = DeployedModel::timing_stub(&SystemConfig::original("mnist"), 7);
    b.bench("run_frame original (functional, 205M MACs)", || {
        original.run_frame(&img).unwrap().0
    });
}
