//! Bench: serving-coordinator overhead. The coordinator must never be the
//! bottleneck (DESIGN.md §Perf L3 target: ≥10k req/s of pure
//! router/batcher overhead with a no-op backend).

use fastcaps::coordinator::batcher::BatchPolicy;
use fastcaps::coordinator::server::{Backend, Server};
use fastcaps::tensor::Tensor;
use fastcaps::util::bench::{report_model, Bencher};
use std::time::Duration;

/// No-op backend: isolates coordinator overhead.
struct NullBackend;

impl Backend for NullBackend {
    fn buckets(&self) -> Vec<usize> {
        vec![1, 8]
    }
    fn run(&mut self, _bucket: usize, images: &[Tensor]) -> fastcaps::Result<Vec<Vec<f32>>> {
        Ok(images.iter().map(|_| vec![0.5; 10]).collect())
    }
    fn input_shape(&self) -> (usize, usize, usize) {
        (1, 28, 28)
    }
}

fn main() {
    let mut b = Bencher::new();

    b.section("batch policy decision (pure logic)");
    let policy = BatchPolicy::new(vec![1, 8], Duration::from_millis(1));
    b.bench("policy.decide x1000", || {
        let mut n = 0usize;
        for q in 0..1000 {
            if policy.decide(q % 16, q % 3 == 0).is_some() {
                n += 1;
            }
        }
        n
    });

    b.section("end-to-end coordinator with no-op backend");
    let n_requests = 2_000;
    let server = Server::start(
        || Ok(Box::new(NullBackend) as Box<dyn Backend>),
        Duration::from_micros(200),
    );
    let img = Tensor::zeros(&[1, 28, 28]);
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let server = &server;
            let img = img.clone();
            scope.spawn(move || {
                for _ in 0..n_requests / 4 {
                    let _ = server.classify(img.clone());
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    report_model("coordinator overhead throughput", m.requests as f64 / wall, "req/s");
    report_model("mean batch size", m.mean_batch_size(), "images");
    report_model("p99 queue+dispatch latency", m.latency.percentile_us(99.0) as f64, "us");
    assert!(
        m.requests as f64 / wall > 10_000.0,
        "coordinator became the bottleneck: {:.0} req/s",
        m.requests as f64 / wall
    );

    b.section("single-request path");
    let server = Server::start(
        || Ok(Box::new(NullBackend) as Box<dyn Backend>),
        Duration::from_micros(50),
    );
    b.bench("classify round-trip (1 client)", || {
        server.classify(img.clone()).unwrap().predicted
    });
    drop(server);
}
