//! Bench: serving-coordinator overhead and executor-pool scaling. The
//! coordinator must never be the bottleneck (DESIGN.md §Perf L3 target:
//! ≥10k req/s of pure router/batcher overhead with a no-op backend),
//! and a compute-bound backend must scale with `replicas(N)` — the
//! host-side analogue of CapsAcc's PE-array parallelism.

use fastcaps::backend::{
    BackendConfig, BackendError, BackendSpec, InferOutput, InferRequest, InferenceBackend,
    SimBackend,
};
use fastcaps::coordinator::batcher::BatchPolicy;
use fastcaps::coordinator::net::{Connection, NetConfig, NetServer};
use fastcaps::coordinator::server::Server;
use fastcaps::data::{generate, Task};
use fastcaps::tensor::Tensor;
use fastcaps::util::bench::{report_model, Bencher};
use std::time::Duration;

fn spec(kind: &str) -> BackendSpec {
    BackendSpec {
        kind: kind.into(),
        model: "null".into(),
        input_shape: (1, 28, 28),
        batch_buckets: vec![1, 8],
        reports_timing: false,
        max_replicas: None,
        compression: None,
        fingerprint: 0,
        routing: String::new(),
        workers: 1,
        coupling_fingerprint: None,
    }
}

/// No-op backend: isolates coordinator overhead.
struct NullBackend(BackendSpec);

impl InferenceBackend for NullBackend {
    fn spec(&self) -> &BackendSpec {
        &self.0
    }
    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
        Ok(InferOutput::untimed(
            req.images.iter().map(|_| vec![0.5; 10]).collect(),
        ))
    }
}

/// Fixed-cost backend: busy-spins ~`cost` per *batch*, so throughput is
/// executor-bound and replica scaling is directly observable.
struct FixedCostBackend {
    spec: BackendSpec,
    cost: Duration,
}

impl InferenceBackend for FixedCostBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }
    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
        let t0 = std::time::Instant::now();
        while t0.elapsed() < self.cost {
            std::hint::spin_loop();
        }
        Ok(InferOutput::untimed(
            req.images.iter().map(|_| vec![0.5; 10]).collect(),
        ))
    }
}

/// Deterministic spin-cost backend for the cache section: busy-spins
/// `cost` per batch like [`FixedCostBackend`], but the lengths are a
/// pure function of the image bits, so a cached response can be checked
/// bit-identical against an uncached run of the same traffic.
struct SpinEchoBackend {
    spec: BackendSpec,
    cost: Duration,
}

impl InferenceBackend for SpinEchoBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }
    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
        let t0 = std::time::Instant::now();
        while t0.elapsed() < self.cost {
            std::hint::spin_loop();
        }
        Ok(InferOutput::untimed(
            req.images
                .iter()
                .map(|img| {
                    let mean = img.sum() / img.len() as f32;
                    (0..10)
                        .map(|k| (mean * (k as f32 + 1.0)).sin() * 0.5 + 0.5)
                        .collect()
                })
                .collect(),
        ))
    }
}

/// Drive `n_requests` from 4 client threads; returns req/s.
fn drive(server: &Server, n_requests: usize) -> f64 {
    let img = Tensor::zeros(&[1, 28, 28]);
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let img = img.clone();
            scope.spawn(move || {
                for _ in 0..n_requests / 4 {
                    server.classify(img.clone()).unwrap();
                }
            });
        }
    });
    n_requests as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let mut b = Bencher::new();

    b.section("batch policy decision (pure logic)");
    let policy = BatchPolicy::new(vec![1, 8], Duration::from_millis(1));
    b.bench("policy.decide x1000", || {
        let mut n = 0usize;
        for q in 0..1000 {
            if policy.decide(q % 16, q % 3 == 0).is_some() {
                n += 1;
            }
        }
        n
    });

    b.section("end-to-end coordinator with no-op backend");
    let server = Server::builder(|| {
        Ok(Box::new(NullBackend(spec("null"))) as Box<dyn InferenceBackend>)
    })
    .max_wait(Duration::from_micros(200))
    .start();
    let rps = drive(&server, 2_000);
    let m = server.shutdown();
    report_model("coordinator overhead throughput", rps, "req/s");
    report_model("mean batch size", m.mean_batch_size(), "images");
    report_model(
        "p99 queue+dispatch latency",
        m.latency.percentile_us(99.0) as f64,
        "us",
    );
    assert!(
        rps > 10_000.0,
        "coordinator became the bottleneck: {rps:.0} req/s"
    );

    b.section("socket front-end: v1 loopback throughput (no-op backend)");
    // The strict in-order v1 path must sustain ≥5k req/s of framed
    // traffic — decode, admission, batch, respond — with zero dropped
    // or hung requests after a graceful drain (ISSUE 5 acceptance
    // gate). Clients pipeline on their own connections; responses
    // stream back in request order.
    {
        let server = Server::builder(|| {
            Ok(Box::new(NullBackend(spec("null"))) as Box<dyn InferenceBackend>)
        })
        .max_wait(Duration::from_micros(200))
        .max_queue_depth(8192)
        .start();
        let net = NetServer::bind("127.0.0.1:0", server).expect("bind loopback");
        let addr = net.local_addr();
        let n_clients = 4usize;
        let per_client = 1000usize;
        let window = 64usize;
        let t0 = std::time::Instant::now();
        let ok_total: usize = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n_clients)
                .map(|_| {
                    scope.spawn(move || {
                        let mut client = Connection::v1_compat(addr).expect("connect");
                        client
                            .set_read_timeout(Some(Duration::from_secs(30)))
                            .unwrap();
                        let img = Tensor::zeros(&[1, 28, 28]);
                        let mut ok = 0usize;
                        let mut inflight = 0usize;
                        for _ in 0..per_client {
                            if inflight == window {
                                client.recv().expect("response");
                                ok += 1;
                                inflight -= 1;
                            }
                            client.submit(&img).expect("send");
                            inflight += 1;
                        }
                        while inflight > 0 {
                            client.recv().expect("tail response");
                            ok += 1;
                            inflight -= 1;
                        }
                        ok
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        let rps = ok_total as f64 / t0.elapsed().as_secs_f64();
        report_model("socket loopback throughput", rps, "req/s");
        assert_eq!(
            ok_total,
            n_clients * per_client,
            "dropped or rejected requests on the socket path"
        );
        assert!(
            rps >= 5_000.0,
            "socket path below the 5k req/s gate: {rps:.0} req/s"
        );
        let m = net.shutdown(); // graceful drain must terminate cleanly
        assert_eq!(
            m.requests as usize, ok_total,
            "server-side accounting disagrees after drain"
        );
        assert_eq!(m.wire_requests as usize, ok_total);
        assert_eq!(m.wire_errors, 0);
        assert_eq!(m.connections_closed, m.connections_opened);
        report_model(
            "socket p99 latency",
            m.latency.percentile_us(99.0) as f64,
            "us",
        );
    }

    b.section("socket front-end: v2 tagged pipeline throughput (2 shards)");
    // The event-driven v2 path is the throughput story of this front
    // end: tagged frames, out-of-order completion, no per-connection
    // threads. Gate: ≥50k req/s on a real multi-core host, scaled down
    // to ≥10k under CI or on small hosts (same shape, smaller machine).
    {
        let ci = std::env::var_os("CI").is_some();
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let server = Server::builder(|| {
            Ok(Box::new(NullBackend(spec("null"))) as Box<dyn InferenceBackend>)
        })
        .max_wait(Duration::from_micros(200))
        .max_queue_depth(16384)
        .start();
        let net = NetServer::bind_with(
            "127.0.0.1:0",
            server,
            NetConfig {
                io_shards: 2,
                ..NetConfig::default()
            },
        )
        .expect("bind loopback");
        let addr = net.local_addr();
        let n_clients = 4usize;
        let per_client = if ci { 2_000usize } else { 16_000usize };
        let window = 128usize;
        let t0 = std::time::Instant::now();
        let ok_total: usize = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n_clients)
                .map(|_| {
                    scope.spawn(move || {
                        let mut client = Connection::connect(addr).expect("connect");
                        client
                            .set_read_timeout(Some(Duration::from_secs(30)))
                            .unwrap();
                        let img = Tensor::zeros(&[1, 28, 28]);
                        let mut ok = 0usize;
                        let mut inflight = std::collections::HashSet::new();
                        for _ in 0..per_client {
                            if inflight.len() == window {
                                let (tag, _) = client.recv().expect("response");
                                assert!(inflight.remove(&tag), "unknown tag {tag}");
                                ok += 1;
                            }
                            inflight.insert(client.submit(&img).expect("submit"));
                        }
                        while !inflight.is_empty() {
                            let (tag, _) = client.recv().expect("tail response");
                            assert!(inflight.remove(&tag), "unknown tag {tag}");
                            ok += 1;
                        }
                        ok
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        let rps = ok_total as f64 / t0.elapsed().as_secs_f64();
        report_model("v2 pipelined throughput", rps, "req/s");
        assert_eq!(
            ok_total,
            n_clients * per_client,
            "dropped or rejected requests on the v2 path"
        );
        let gate = if ci || cores < 8 { 10_000.0 } else { 50_000.0 };
        assert!(
            rps >= gate,
            "v2 pipeline below the {gate:.0} req/s gate: {rps:.0} req/s"
        );
        let m = net.shutdown();
        assert_eq!(m.wire_requests as usize, ok_total);
        assert_eq!(m.wire_errors, 0);
        assert_eq!(m.connections_closed, m.connections_opened);
        report_model(
            "v2 socket p99 latency",
            m.latency.percentile_us(99.0) as f64,
            "us",
        );
    }

    b.section("socket front-end: concurrent connections, constant threads");
    // Connections are event-loop state, not threads: holding thousands
    // of idle connections must not grow the thread count, and sampled
    // connections must still classify. Targets 10k when the fd limit
    // allows (raised toward the hard cap on linux).
    #[cfg(target_os = "linux")]
    {
        fn nofile_limit_raised() -> u64 {
            #[repr(C)]
            struct RLimit {
                cur: u64,
                max: u64,
            }
            extern "C" {
                fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
                fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
            }
            const RLIMIT_NOFILE: i32 = 7;
            let mut lim = RLimit { cur: 0, max: 0 };
            unsafe {
                if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
                    return 1024;
                }
                let want = RLimit {
                    cur: lim.max,
                    max: lim.max,
                };
                let _ = setrlimit(RLIMIT_NOFILE, &want);
                if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
                    return 1024;
                }
            }
            lim.cur
        }
        fn thread_count() -> usize {
            std::fs::read_to_string("/proc/self/status")
                .ok()
                .and_then(|s| {
                    s.lines()
                        .find_map(|l| l.strip_prefix("Threads:"))
                        .and_then(|v| v.trim().parse().ok())
                })
                .expect("Threads: line in /proc/self/status")
        }
        // Both endpoints live in this process: 2 fds per connection,
        // plus headroom for everything else the process has open.
        let lim = nofile_limit_raised();
        let target = ((lim.saturating_sub(1_000) / 2) as usize).clamp(256, 10_000);
        let server = Server::builder(|| {
            Ok(Box::new(NullBackend(spec("null"))) as Box<dyn InferenceBackend>)
        })
        .max_wait(Duration::from_micros(200))
        .start();
        let net = NetServer::bind_with(
            "127.0.0.1:0",
            server,
            NetConfig {
                io_shards: 4,
                ..NetConfig::default()
            },
        )
        .expect("bind loopback");
        let addr = net.local_addr();
        let baseline = thread_count();
        let n_live = 8usize.min(target);
        let mut live: Vec<Connection> = (0..n_live)
            .map(|_| Connection::connect(addr).expect("connect"))
            .collect();
        let idle: Vec<std::net::TcpStream> = (0..target - n_live)
            .map(|_| std::net::TcpStream::connect(addr).expect("connect"))
            .collect();
        let t0 = std::time::Instant::now();
        while (net.server().metrics().connections_opened as usize) < target {
            assert!(
                t0.elapsed() < Duration::from_secs(60),
                "server never accepted {target} connections"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
        let with_conns = thread_count();
        report_model("concurrent connections held", target as f64, "conns");
        assert!(
            with_conns <= baseline + 8,
            "{target} connections grew the thread count {baseline} -> {with_conns}"
        );
        // The sampled connections still serve under the load of holding
        // every other connection open.
        let img = Tensor::zeros(&[1, 28, 28]);
        for c in &mut live {
            c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            c.classify(&img).expect("sampled connection classify");
        }
        drop(idle);
        drop(live);
        let m = net.shutdown();
        assert!(m.connections_opened as usize >= target);
        assert_eq!(
            m.shard_connections.iter().sum::<u64>(),
            m.connections_opened,
            "per-shard counters must partition the accept count"
        );
        assert!(
            m.shard_connections.iter().all(|&c| c > 0),
            "round-robin left a shard empty: {:?}",
            m.shard_connections
        );
    }
    #[cfg(not(target_os = "linux"))]
    println!("(non-linux host: skipping the concurrent-connection section)");

    b.section("executor pool scaling (fixed 1ms/batch backend)");
    let mut scaling = Vec::new();
    for replicas in [1usize, 2, 4] {
        let server = Server::builder(|| {
            Ok(Box::new(FixedCostBackend {
                spec: spec("fixed-cost"),
                cost: Duration::from_millis(1),
            }) as Box<dyn InferenceBackend>)
        })
        .replicas(replicas)
        .max_wait(Duration::from_micros(200))
        .max_queue_depth(4096)
        .start();
        // Open-loop burst: keep the queue deep so every replica always
        // has a full bucket to pull — the speedup is then bounded only
        // by batch cost and core count, not client round-trips.
        let n = 400usize;
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..n)
            .map(|_| server.submit(Tensor::zeros(&[1, 28, 28])).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let rps = n as f64 / t0.elapsed().as_secs_f64();
        server.shutdown();
        report_model(&format!("replicas={replicas}"), rps, "req/s");
        scaling.push((replicas, rps));
    }
    let r1 = scaling[0].1;
    let r2 = scaling[1].1;
    report_model("pool speedup 2 vs 1 replicas", r2 / r1, "x");
    // Two busy-spinning replicas can only beat one when there are at
    // least two cores to run them on.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores >= 2 {
        assert!(
            r2 > r1 * 1.2,
            "executor pool failed to scale: {r1:.0} req/s @1 vs {r2:.0} req/s @2"
        );
    } else {
        println!("(single-core host: skipping the pool-scaling assertion)");
    }

    b.section("intra-replica batch sharding (fp32 oracle forward, batch 16)");
    // The multi-core data-reuse path: one replica shards a 16-frame
    // batch over scoped worker threads. Frames are independent, so the
    // sharded outputs are bit-identical to the serial ones (asserted
    // here and property-tested in capsnet/fpga); the gate is the
    // speedup — ≥3x at 4 workers when the host has the cores.
    {
        use fastcaps::capsnet::{weights::Weights, CapsNet};
        use fastcaps::config::CapsNetConfig;
        use fastcaps::routing::RoutingMode;
        let arch = CapsNetConfig::paper_pruned_mnist();
        let mode = RoutingMode::Iterative(arch.routing_iters);
        let net = CapsNet {
            weights: Weights::random(&arch, &mut fastcaps::util::rng::Rng::new(7)),
            config: arch,
        };
        let images = generate(Task::Digits, 16, 77).images;
        let serial = net.forward_batch_sharded(&images, mode, None, 1).unwrap();
        let sharded = net.forward_batch_sharded(&images, mode, None, 4).unwrap();
        for (a, s) in serial.iter().zip(&sharded) {
            assert_eq!(
                a.class_lengths(),
                s.class_lengths(),
                "sharded batch diverged from the serial reference"
            );
        }
        let serial_ns = b
            .bench("forward_batch_sharded workers=1", || {
                net.forward_batch_sharded(&images, mode, None, 1).unwrap().len()
            })
            .mean_ns;
        let sharded_ns = b
            .bench("forward_batch_sharded workers=4", || {
                net.forward_batch_sharded(&images, mode, None, 4).unwrap().len()
            })
            .mean_ns;
        let speedup = serial_ns / sharded_ns;
        report_model("sharding speedup 4 vs 1 workers", speedup, "x");
        if cores >= 4 {
            assert!(
                speedup >= 3.0,
                "batch sharding below the 3x gate at 4 workers on a \
                 {cores}-core host: {speedup:.2}x"
            );
        } else {
            println!("({cores}-core host: skipping the 3x sharding assertion)");
        }
    }

    b.section("batch-native sim path vs the per-frame reference loop (bucket 8)");
    // The batched datapath (slice-optimized conv, weight-stationary û
    // projection into a persistent scratch, one cycle-model pass per
    // batch) must beat running the reference `run_frame` once per image.
    // Values are bitwise identical between the two paths (asserted by
    // fpga/backend tests); this guards the host-side speedup.
    let mut sim = SimBackend::from_config(&BackendConfig::default()).unwrap();
    let reference = sim.model().clone();
    let data = generate(Task::Digits, 8, 42);
    let req = InferRequest::new(data.images.clone());
    let per_frame_ns = b
        .bench("per-frame run_frame × 8 (reference loop)", || {
            data.images
                .iter()
                .map(|img| reference.run_frame(img).unwrap().0)
                .sum::<usize>()
        })
        .mean_ns;
    let batched_ns = b
        .bench("SimBackend::infer batch=8 (batch-native)", || {
            sim.infer(&req).unwrap().lengths.len()
        })
        .mean_ns;
    let speedup = per_frame_ns / batched_ns;
    report_model("batched speedup vs per-frame loop", speedup, "x");
    assert!(
        speedup >= 1.3,
        "batch-native sim path regressed: only {speedup:.2}x over the per-frame loop"
    );

    b.section("sparse sim vs dense sim (modeled steady-state, paper survivor counts)");
    // Serving-side view of the sparsity payoff: the sim-sparse geometry
    // (LAKP survivors on the full architecture) must strictly dominate
    // the dense simulator's modeled steady-state FPS.
    {
        use fastcaps::config::SystemConfig;
        use fastcaps::fpga::DeployedModel;
        let dense_fps = DeployedModel::timing_stub(&SystemConfig::original("mnist"), 7)
            .estimate_batch(8)
            .steady_state_fps();
        let sparse_fps = DeployedModel::timing_stub(&SystemConfig::masked("mnist"), 7)
            .estimate_batch(8)
            .steady_state_fps();
        report_model("dense sim steady-state", dense_fps, "FPS");
        report_model("sparse sim steady-state", sparse_fps, "FPS");
        assert!(
            sparse_fps > dense_fps,
            "sparse sim must strictly dominate dense sim: {sparse_fps:.1} vs {dense_fps:.1}"
        );
    }

    b.section("content-addressed cache: 90% duplicate traffic (500us/frame backend)");
    // DESIGN.md §Perf L3 target: at 90% duplicate traffic the cache must
    // buy ≥10x end-to-end throughput over the identical uncached server,
    // with bit-identical responses. The duplicate stream mixes a hot
    // 8-frame pool (90%) with a repeating 100-frame long tail (10%), so
    // even the "cold" fraction amortizes — ~108 distinct frames ever
    // reach the backend out of 2000 requests.
    {
        use fastcaps::cache::CacheConfig;
        let hot = generate(Task::Digits, 8, 101).images;
        let tail = generate(Task::Digits, 100, 202).images;
        let mut rng = fastcaps::util::rng::Rng::new(303);
        let traffic: Vec<Tensor> = (0..2000)
            .map(|i| {
                if rng.f64() < 0.9 {
                    hot[rng.below(hot.len())].clone()
                } else {
                    tail[i % tail.len()].clone()
                }
            })
            .collect();
        let builder = || {
            Server::builder(|| {
                let mut s = spec("spin-echo");
                // Bucket 1: every admitted request pays the full spin,
                // so the comparison isolates the cache, not batching.
                s.batch_buckets = vec![1];
                Ok(Box::new(SpinEchoBackend {
                    spec: s,
                    cost: Duration::from_micros(500),
                }) as Box<dyn InferenceBackend>)
            })
            .max_wait(Duration::from_micros(50))
        };
        let run = |server: &Server| {
            let t0 = std::time::Instant::now();
            let responses: Vec<(usize, Vec<u32>)> = traffic
                .iter()
                .map(|img| {
                    let r = server.classify(img.clone()).unwrap();
                    (r.predicted, r.lengths.iter().map(|x| x.to_bits()).collect())
                })
                .collect();
            (
                traffic.len() as f64 / t0.elapsed().as_secs_f64(),
                responses,
            )
        };
        let uncached = builder().start();
        let (rps_u, resp_u) = run(&uncached);
        uncached.shutdown();
        let cached = builder().cache(CacheConfig::with_entries(1024)).start();
        let (rps_c, resp_c) = run(&cached);
        let m = cached.shutdown();
        report_model("uncached throughput", rps_u, "req/s");
        report_model("cached throughput", rps_c, "req/s");
        report_model("cache speedup", rps_c / rps_u, "x");
        assert_eq!(
            resp_u, resp_c,
            "cached responses must be bit-identical to uncached ones"
        );
        assert!(
            rps_c >= 10.0 * rps_u,
            "cache below the 10x gate at 90% duplicates: \
             {rps_c:.0} vs {rps_u:.0} req/s"
        );
        assert!(m.cache_hits > 0, "duplicate traffic produced no hits");
        assert_eq!(
            m.cache_hits + m.cache_misses + m.cache_coalesced,
            m.requests,
            "cache accounting broken"
        );
        assert_eq!(m.cache_stale, 0, "stale sightings must be impossible");
        assert!(
            (m.cache_misses as usize) <= 108,
            "more backend passes than distinct frames: {}",
            m.cache_misses
        );
    }

    b.section("single-request path");
    let server = Server::builder(|| {
        Ok(Box::new(NullBackend(spec("null"))) as Box<dyn InferenceBackend>)
    })
    .max_wait(Duration::from_micros(50))
    .start();
    let img = Tensor::zeros(&[1, 28, 28]);
    b.bench("classify round-trip (1 client)", || {
        server.classify(img.clone()).unwrap().predicted
    });
    drop(server);
}
