//! Bench: Table III — proposed CapsNet on F-MNIST (modeled latency
//! 1.07 ms in the paper) plus host-cost regression guard.

use fastcaps::config::SystemConfig;
use fastcaps::data::{generate, Task};
use fastcaps::fpga::DeployedModel;
use fastcaps::util::bench::{report_model, Bencher};

fn main() {
    let mut b = Bencher::new();
    b.section("Table III — modeled F-MNIST latency");
    for (name, cfg, paper_s) in [
        ("pruned-fmnist", SystemConfig::pruned("fmnist"), 1.0 / 48.0),
        ("proposed-fmnist", SystemConfig::proposed("fmnist"), 0.00107),
    ] {
        let model = DeployedModel::timing_stub(&cfg, 7);
        let t = model.estimate_frame();
        report_model(
            &format!("{name} modeled latency (paper {paper_s:.5}s)"),
            t.latency_s(),
            "s/frame",
        );
    }

    b.section("host cost");
    let model = DeployedModel::timing_stub(&SystemConfig::proposed("fmnist"), 7);
    let img = generate(Task::Garments, 1, 3).images.remove(0);
    b.bench("estimate_frame fmnist", || {
        model.estimate_frame().total_cycles()
    });
    b.bench("run_frame fmnist (functional)", || {
        model.run_frame(&img).unwrap().0
    });
}
