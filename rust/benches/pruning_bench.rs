//! Bench: pruning engines on the full-size CapsNet conv tensors
//! (LAKP scoring must stay negligible next to training — the paper calls
//! it "computationally efficient").

use fastcaps::capsnet::weights::Weights;
use fastcaps::config::CapsNetConfig;
use fastcaps::pruning::{capsule, kp, lakp, magnitude, AdjacencyNorms};
use fastcaps::util::bench::Bencher;
use fastcaps::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let cfg = CapsNetConfig::paper_full("capsnet-mnist");
    let w = Weights::random(&cfg, &mut Rng::new(5));
    let adj = AdjacencyNorms {
        prev: AdjacencyNorms::prev_from_conv(&w.conv1_w),
        next: AdjacencyNorms::next_from_digitcaps(&w.w_ij, cfg.pc_types, cfg.pc_dim),
    };

    b.section("pruning the PrimaryCaps layer (65,536 kernels / 5.3M params)");
    b.bench("LAKP score + mask @99%", || {
        lakp::prune_layer(&w.pc_w, &adj, 0.99).mask.survived()
    });
    b.bench("KP score + mask @99%", || {
        kp::prune_layer(&w.pc_w, 0.99).mask.survived()
    });
    b.bench("unstructured magnitude @99%", || {
        magnitude::prune_layer(&w.pc_w, 0.99).survived()
    });
    b.bench("capsule-type pruning @75%", || {
        capsule::prune_types(&w.pc_w, cfg.pc_dim, 0.75).survived()
    });

    b.section("adjacency norms (Eq. 1 inputs)");
    b.bench("prev norms (conv1)", || {
        AdjacencyNorms::prev_from_conv(&w.conv1_w).len()
    });
    b.bench("next norms (DigitCaps transform)", || {
        AdjacencyNorms::next_from_digitcaps(&w.w_ij, cfg.pc_types, cfg.pc_dim).len()
    });
}
