//! Bench: pruning engines on the full-size CapsNet conv tensors
//! (LAKP scoring must stay negligible next to training — the paper calls
//! it "computationally efficient"), and the prune→execute payoff: the
//! sparse-compiled forward must beat the masked-dense oracle by ≥5× at
//! the paper's compression rate (99.26% of MNIST conv kernels removed —
//! a masked-dense forward still multiplies through every zero).

use fastcaps::capsnet::weights::Weights;
use fastcaps::capsnet::{CapsNet, CompiledCapsNet};
use fastcaps::config::{CapsNetConfig, SparsityPlan};
use fastcaps::data::{generate, Task};
use fastcaps::pruning::{capsule, kp, lakp, magnitude, AdjacencyNorms, NetworkMasks};
use fastcaps::util::bench::{report_model, Bencher};
use fastcaps::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let cfg = CapsNetConfig::paper_full("capsnet-mnist");
    let w = Weights::random(&cfg, &mut Rng::new(5));
    let adj = AdjacencyNorms {
        prev: AdjacencyNorms::prev_from_conv(&w.conv1_w),
        next: AdjacencyNorms::next_from_digitcaps(&w.w_ij, cfg.pc_types, cfg.pc_dim),
    };

    b.section("pruning the PrimaryCaps layer (65,536 kernels / 5.3M params)");
    b.bench("LAKP score + mask @99%", || {
        lakp::prune_layer(&w.pc_w, &adj, 0.99).mask.survived()
    });
    b.bench("KP score + mask @99%", || {
        kp::prune_layer(&w.pc_w, 0.99).mask.survived()
    });
    b.bench("unstructured magnitude @99%", || {
        magnitude::prune_layer(&w.pc_w, 0.99).survived()
    });
    b.bench("capsule-type pruning @75%", || {
        capsule::prune_types(&w.pc_w, cfg.pc_dim, 0.75).survived()
    });

    b.section("adjacency norms (Eq. 1 inputs)");
    b.bench("prev norms (conv1)", || {
        AdjacencyNorms::prev_from_conv(&w.conv1_w).len()
    });
    b.bench("next norms (DigitCaps transform)", || {
        AdjacencyNorms::next_from_digitcaps(&w.w_ij, cfg.pc_types, cfg.pc_dim).len()
    });

    b.section("prune → execute: sparse-compiled vs masked-dense oracle (paper compression)");
    // The paper's MNIST deployment point: 64 + 423 of 65,792 conv kernels
    // survive (99.26% compression). The masked-dense oracle pays the full
    // dense multiply cost for the ~1%-alive model; the compiled path
    // executes only survivors through the Index-Control CSR packing.
    let net = CapsNet {
        config: cfg.clone(),
        weights: w.clone(),
    };
    let masks = NetworkMasks::from_plan(&net.weights, &cfg, &SparsityPlan::paper_mnist());
    let dense = net.masked(&masks);
    let compiled = CompiledCapsNet::compile(&net, &masks).unwrap();
    let stats = compiled.stats();
    report_model("conv kernels pruned", stats.pruned_pct(), "%");

    let frame = generate(Task::Digits, 1, 3).images.remove(0);
    // Same inputs, same outputs: the compiled path is bit-exact to the
    // masked-dense reference (property-tested in capsnet/compiled.rs;
    // spot-checked here so the speedup below compares equal work).
    let want = dense.forward(&frame).unwrap();
    let got = compiled.forward(&frame).unwrap();
    assert_eq!(got.routing.v, want.routing.v, "compiled diverged from masked-dense");
    assert_eq!(got.primary_caps, want.primary_caps);

    let dense_ns = b
        .bench("masked-dense forward (full arch, 99.26% zeros)", || {
            dense.forward(&frame).unwrap().routing.v.len()
        })
        .mean_ns;
    let sparse_ns = b
        .bench("sparse-compiled forward (survivors only)", || {
            compiled.forward(&frame).unwrap().routing.v.len()
        })
        .mean_ns;
    let speedup = dense_ns / sparse_ns;
    report_model("sparse speedup over masked-dense", speedup, "x");
    assert!(
        speedup >= 5.0,
        "sparse-compiled oracle must be ≥5x the dense oracle at paper \
         compression rates, got {speedup:.2}x"
    );

    b.section("modeled FPGA serving: sparse sim vs dense sim (paper survivor counts)");
    // The same LAKP masks, deployed on the fixed-point FPGA simulator:
    // the CSR cycle model prices only survivors, so the sparse sim's
    // steady-state FPS must strictly dominate the dense sim's.
    use fastcaps::config::SystemConfig;
    use fastcaps::fpga::DeployedModel;
    let sparse_sys = SystemConfig::masked("mnist");
    let sparse_sim = DeployedModel::new(sparse_sys, &w, &masks.conv1, &masks.pc).unwrap();
    let dense_sim = DeployedModel::timing_stub(&SystemConfig::original("mnist"), 7);
    let sparse_fps = sparse_sim.estimate_batch(8).steady_state_fps();
    let dense_fps = dense_sim.estimate_batch(8).steady_state_fps();
    report_model("dense sim steady-state", dense_fps, "FPS");
    report_model("sparse sim steady-state", sparse_fps, "FPS");
    assert!(
        sparse_fps > dense_fps,
        "sparse sim must strictly dominate the dense sim at the paper's \
         survivor counts: {sparse_fps:.1} vs {dense_fps:.1} FPS"
    );
    // F-MNIST plan point too (timing-only stubs price the geometry).
    let sparse_f = DeployedModel::timing_stub(&SystemConfig::masked("fmnist"), 7);
    let dense_f = DeployedModel::timing_stub(&SystemConfig::original("fmnist"), 7);
    assert!(
        sparse_f.estimate_batch(8).steady_state_fps()
            > dense_f.estimate_batch(8).steady_state_fps(),
        "f-mnist sparse sim must dominate the dense sim"
    );

    b.section("routing fast path: accumulated coefficients vs iterative(3)");
    // Modeled serving: the accumulated deployment drops the whole routing
    // stage AND the per-iteration û DDR replay, so the sim-sparse
    // steady-state FPS must at least double (ISSUE 7 acceptance gate).
    let calib = generate(Task::Digits, 32, 0xacc0).images;
    let mut acc_sim =
        DeployedModel::new(SystemConfig::masked("mnist"), &w, &masks.conv1, &masks.pc).unwrap();
    let coupling_q = acc_sim.accumulate_coupling(&calib).unwrap();
    acc_sim.bake_accumulated(&coupling_q).unwrap();
    let iter_fps = sparse_sim.estimate_batch(16).steady_state_fps();
    let acc_fps = acc_sim.estimate_batch(16).steady_state_fps();
    report_model("sim-sparse iterative(3) steady-state", iter_fps, "FPS");
    report_model("sim-sparse accumulated steady-state", acc_fps, "FPS");
    assert!(
        acc_fps >= 2.0 * iter_fps,
        "accumulated routing must at least double modeled sim-sparse FPS: \
         {acc_fps:.1} vs {iter_fps:.1}"
    );

    // Oracle accuracy: the accumulated fast path must track the iterative
    // reference within 1 percentage point absolute on both datasets
    // (disjoint calibration / eval seeds).
    use fastcaps::routing::RoutingMode;
    for (ds, task, arch) in [
        ("mnist", Task::Digits, CapsNetConfig::paper_pruned_mnist()),
        ("fmnist", Task::Garments, CapsNetConfig::paper_pruned_fmnist()),
    ] {
        let weights = Weights::random(&arch, &mut Rng::new(7));
        let net = CapsNet {
            config: arch,
            weights,
        };
        let coupling = net
            .accumulate_coupling(&generate(task, 32, 0xacc0).images)
            .unwrap();
        let eval = generate(task, 256, 0xe7a1);
        let (mut hit_iter, mut hit_acc) = (0usize, 0usize);
        for (img, &label) in eval.images.iter().zip(&eval.labels) {
            hit_iter += usize::from(net.forward(img).unwrap().predicted_class() == label);
            hit_acc += usize::from(
                net.forward_mode(img, RoutingMode::Accumulated, Some(&coupling))
                    .unwrap()
                    .predicted_class()
                    == label,
            );
        }
        let n = eval.images.len() as f64;
        let (acc_i, acc_a) = (100.0 * hit_iter as f64 / n, 100.0 * hit_acc as f64 / n);
        report_model(
            &format!("{ds} accuracy delta (accumulated − iterative)"),
            acc_a - acc_i,
            "pp",
        );
        assert!(
            (acc_i - acc_a).abs() <= 1.0,
            "accumulated routing drifted >1pp from iterative on {ds}: \
             {acc_i:.2}% vs {acc_a:.2}%"
        );
    }

    b.section("SIMD dispatch: functional Q8.8/Q4.12 forward, scalar vs AVX2");
    simd_forward_section(&mut b, &sparse_sim);
}

/// Force each dispatch level in turn and run the functional fixed-point
/// batch forward. The integer kernels are bit-identical by construction
/// (wide accumulators make every summation order exact — zero drift, a
/// stronger property than the ≤1e-5 gate the issue allows), so the AVX2
/// pass must reproduce the scalar outputs bit-for-bit AND beat it by
/// ≥1.5× wall clock at batch 16.
#[cfg(target_arch = "x86_64")]
fn simd_forward_section(b: &mut Bencher, sim: &fastcaps::fpga::DeployedModel) {
    use fastcaps::fpga::BatchScratch;
    use fastcaps::kernels::{self, SimdLevel};
    if !kernels::avx2_supported() {
        println!("  (no AVX2 on this host; SIMD forward gate skipped)");
        return;
    }
    let images = generate(Task::Digits, 16, 0x51D0).images;
    let mut scratch = BatchScratch::new();

    kernels::force_level(SimdLevel::Scalar);
    let want = sim.run_batch(&images, &mut scratch).unwrap();
    let scalar_ns = b
        .bench("sim-sparse run_batch(16) scalar", || {
            sim.run_batch(&images, &mut scratch).unwrap().classes.len()
        })
        .mean_ns;

    kernels::force_level(SimdLevel::Avx2);
    let got = sim.run_batch(&images, &mut scratch).unwrap();
    assert_eq!(got.classes, want.classes, "AVX2 forward changed predictions");
    let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
    for (frame, (sc, av)) in want.lengths.iter().zip(&got.lengths).enumerate() {
        assert_eq!(
            bits(sc),
            bits(av),
            "AVX2 forward is not bit-identical to scalar at frame {frame}"
        );
    }
    let avx2_ns = b
        .bench("sim-sparse run_batch(16) avx2", || {
            sim.run_batch(&images, &mut scratch).unwrap().classes.len()
        })
        .mean_ns;

    let speedup = scalar_ns / avx2_ns.max(1e-9);
    report_model("AVX2 functional forward speedup", speedup, "x");
    assert!(
        speedup >= 1.5,
        "AVX2 batch-16 functional forward must be ≥1.5x scalar, got {speedup:.2}x"
    );
}

#[cfg(not(target_arch = "x86_64"))]
fn simd_forward_section(_b: &mut Bencher, _sim: &fastcaps::fpga::DeployedModel) {
    println!("  (non-x86_64 host; SIMD forward gate skipped)");
}
