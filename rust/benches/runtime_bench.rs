//! Bench: PJRT runtime hot path — per-batch execution cost for the b=1
//! and b=8 buckets (the coordinator's executor step). Requires
//! `make artifacts`; skips cleanly otherwise.

use fastcaps::data::{generate, Task};
use fastcaps::util::bench::Bencher;
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("skipping runtime bench: no artifacts/ (run `make artifacts`)");
        return;
    }
    let rt = match fastcaps::runtime::Runtime::open(dir) {
        Ok(rt) => rt,
        // Built without the `pjrt` feature.
        Err(e) => {
            println!("skipping runtime bench: {e}");
            return;
        }
    };
    let weights = dir.join("weights-mnist.fcw");
    let e1 = rt.engine("capsnet-mnist-pruned", 1, &weights).expect("b1 engine");
    let e8 = rt.engine("capsnet-mnist-pruned", 8, &weights).expect("b8 engine");

    let mut b = Bencher::new();
    b.section("PJRT execution (pruned MNIST model)");
    let data = generate(Task::Digits, 8, 3);
    let one = &data.images[..1];
    let m1 = b.bench("run_batch b=1", || e1.run_batch(one).unwrap().len()).clone();
    let m8 = b
        .bench("run_batch b=8", || e8.run_batch(&data.images).unwrap().len())
        .clone();
    println!(
        "per-image: b=1 {:.2} ms, b=8 {:.2} ms ({:.2}x batching win)",
        m1.mean_ns / 1e6,
        m8.mean_ns / 8.0 / 1e6,
        m1.mean_ns / (m8.mean_ns / 8.0)
    );
}
