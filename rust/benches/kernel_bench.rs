//! Scalar-vs-AVX2 microbenchmarks for the SIMD kernel layer
//! (`fastcaps::kernels`), over the shapes the datapaths actually run:
//! the Q8.8 conv-row MAC, the Q4.12 û-projection / routing-FC axpy,
//! the routing reductions (dot/sumsq/sum/max), the squash requantize
//! writeback, and the fp32 elementwise kernels (axpy/mul/div).
//!
//! On hosts with AVX2 the run gates on a ≥2× geometric-mean speedup of
//! the vector path over the scalar path (both called directly, no
//! dispatch). Elsewhere the comparison is skipped cleanly — there is
//! only one implementation to measure.
//!
//! Each timed sample batches `REPS` kernel calls: a single call is a
//! handful of nanoseconds, well under the sampling-clock overhead, and
//! an unbatched comparison would gate on `Instant::now` instead of the
//! kernels. Inputs pass through `black_box` so the loop cannot be
//! hoisted or folded.
//!
//! Every pair first asserts the two implementations agree bit-for-bit
//! on its operands (the module's property tests cover the general
//! claim; this pins it on the benchmarked shapes too).

use fastcaps::util::bench::Bencher;
use fastcaps::util::rng::Rng;
use std::hint::black_box;

/// Kernel calls per timed sample.
const REPS: usize = 512;

fn rand_i16(r: &mut Rng) -> i16 {
    (r.below(65536) as i32 - 32768) as i16
}

/// Operand set shared by both paths: conv output row (96-wide, the
/// Q8.8 conv-row MAC), û projection / routing-FC row (dc_dim = 16),
/// reduction rows (64-wide), and a squash requantize row.
struct Operands {
    conv_w: Vec<i16>,
    conv_acc: Vec<i64>,
    fc_w: Vec<i16>,
    fc_acc: Vec<i64>,
    red_a: Vec<i16>,
    red_b: Vec<i16>,
    sq_in: Vec<i16>,
    f32_w: Vec<f32>,
    f32_acc: Vec<f32>,
}

impl Operands {
    fn generate() -> Operands {
        let mut rng = Rng::new(0xBE9C);
        Operands {
            conv_w: (0..96).map(|_| rand_i16(&mut rng)).collect(),
            conv_acc: vec![3i64; 96],
            fc_w: (0..16).map(|_| rand_i16(&mut rng)).collect(),
            fc_acc: vec![-7i64; 16],
            red_a: (0..64).map(|_| rand_i16(&mut rng)).collect(),
            red_b: (0..64).map(|_| rand_i16(&mut rng)).collect(),
            sq_in: (0..16).map(|_| rand_i16(&mut rng)).collect(),
            f32_w: (0..64).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            f32_acc: vec![0.25f32; 64],
        }
    }
}

fn main() {
    #[cfg(target_arch = "x86_64")]
    if fastcaps::kernels::avx2_supported() {
        gated_comparison();
        return;
    }
    scalar_only();
}

/// Non-AVX2 hosts: time the scalar kernels so the bench still produces
/// numbers, and skip the speedup gate (nothing to compare against).
fn scalar_only() {
    use fastcaps::kernels::scalar;
    let mut op = Operands::generate();
    let mut b = Bencher::new();
    b.section("kernel microbench (scalar only — host has no AVX2)");
    b.bench("conv-row axpy_i16 scalar x512", || {
        for _ in 0..REPS {
            scalar::axpy_i16(&mut op.conv_acc, 77, black_box(&op.conv_w));
        }
    });
    b.bench("fc axpy_i16 scalar x512", || {
        for _ in 0..REPS {
            scalar::axpy_i16(&mut op.fc_acc, -1234, black_box(&op.fc_w));
        }
    });
    b.bench("dot_i16 scalar x512", || {
        for _ in 0..REPS {
            black_box(scalar::dot_i16(black_box(&op.red_a), &op.red_b));
        }
    });
    b.bench("scale_i16_q scalar x512", || {
        let mut out = [0i16; 16];
        for _ in 0..REPS {
            scalar::scale_i16_q::<12>(black_box(&op.sq_in), 2048, &mut out);
            black_box(&mut out);
        }
    });
    b.bench("axpy_f32 scalar x512", || {
        for _ in 0..REPS {
            scalar::axpy_f32(&mut op.f32_acc, 0.5, black_box(&op.f32_w));
        }
    });
    println!("\nno AVX2 on this host; scalar-vs-vector gate skipped");
}

#[cfg(target_arch = "x86_64")]
fn gated_comparison() {
    use fastcaps::kernels::{avx2, scalar};

    let op = Operands::generate();

    // Bit-identity spot checks on the benchmarked shapes.
    {
        let mut a = op.conv_acc.clone();
        let mut v = op.conv_acc.clone();
        scalar::axpy_i16(&mut a, 77, &op.conv_w);
        unsafe { avx2::axpy_i16(&mut v, 77, &op.conv_w) };
        assert_eq!(a, v, "axpy_i16 bit-identity");
        assert_eq!(
            scalar::dot_i16(&op.red_a, &op.red_b),
            unsafe { avx2::dot_i16(&op.red_a, &op.red_b) },
            "dot_i16 bit-identity"
        );
        assert_eq!(
            scalar::sum_i16(&op.red_a),
            unsafe { avx2::sum_i16(&op.red_a) },
            "sum_i16 bit-identity"
        );
        let mut s = [0i16; 16];
        let mut t = [0i16; 16];
        scalar::scale_i16_q::<12>(&op.sq_in, 2048, &mut s);
        unsafe { avx2::scale_i16_q::<12>(&op.sq_in, 2048, &mut t) };
        assert_eq!(s, t, "scale_i16_q bit-identity");
        let mut fa = op.f32_acc.clone();
        let mut fv = op.f32_acc.clone();
        scalar::axpy_f32(&mut fa, 0.5, &op.f32_w);
        unsafe { avx2::axpy_f32(&mut fv, 0.5, &op.f32_w) };
        let bits = |x: &[f32]| x.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&fa), bits(&fv), "axpy_f32 bit-identity");
        let mut ms = vec![0.0f32; op.f32_w.len()];
        let mut mv = vec![0.0f32; op.f32_w.len()];
        scalar::mul_f32(&op.f32_w, 1.5, &mut ms);
        unsafe { avx2::mul_f32(&op.f32_w, 1.5, &mut mv) };
        assert_eq!(bits(&ms), bits(&mv), "mul_f32 bit-identity");
        let mut ds = op.f32_w.clone();
        let mut dv = op.f32_w.clone();
        scalar::div_in_place_f32(&mut ds, 3.0);
        unsafe { avx2::div_in_place_f32(&mut dv, 3.0) };
        assert_eq!(bits(&ds), bits(&dv), "div_in_place_f32 bit-identity");
    }

    let mut b = Bencher::new();
    let mut speedups: Vec<(&'static str, f64)> = Vec::new();

    b.section("Q8.8 conv-row MAC (96-wide axpy_i16, x512)");
    {
        let mut acc = op.conv_acc.clone();
        let s = b
            .bench("conv axpy_i16 scalar", || {
                for _ in 0..REPS {
                    scalar::axpy_i16(&mut acc, 77, black_box(&op.conv_w));
                }
            })
            .mean_ns;
        let mut acc = op.conv_acc.clone();
        let v = b
            .bench("conv axpy_i16 avx2", || {
                for _ in 0..REPS {
                    unsafe { avx2::axpy_i16(&mut acc, 77, black_box(&op.conv_w)) };
                }
            })
            .mean_ns;
        speedups.push(("conv axpy_i16", s / v.max(1e-9)));
    }

    b.section("Q4.12 û-projection / routing-FC (16-wide axpy_i16, x512)");
    {
        let mut acc = op.fc_acc.clone();
        let s = b
            .bench("fc axpy_i16 scalar", || {
                for _ in 0..REPS {
                    scalar::axpy_i16(&mut acc, -1234, black_box(&op.fc_w));
                }
            })
            .mean_ns;
        let mut acc = op.fc_acc.clone();
        let v = b
            .bench("fc axpy_i16 avx2", || {
                for _ in 0..REPS {
                    unsafe { avx2::axpy_i16(&mut acc, -1234, black_box(&op.fc_w)) };
                }
            })
            .mean_ns;
        speedups.push(("fc axpy_i16", s / v.max(1e-9)));
    }

    b.section("routing reductions (64-wide, x512)");
    {
        let s = b
            .bench("dot_i16 scalar", || {
                for _ in 0..REPS {
                    black_box(scalar::dot_i16(black_box(&op.red_a), &op.red_b));
                }
            })
            .mean_ns;
        let v = b
            .bench("dot_i16 avx2", || {
                for _ in 0..REPS {
                    black_box(unsafe { avx2::dot_i16(black_box(&op.red_a), &op.red_b) });
                }
            })
            .mean_ns;
        speedups.push(("dot_i16", s / v.max(1e-9)));
        let s = b
            .bench("sumsq_i16 scalar", || {
                for _ in 0..REPS {
                    black_box(scalar::sumsq_i16(black_box(&op.red_a)));
                }
            })
            .mean_ns;
        let v = b
            .bench("sumsq_i16 avx2", || {
                for _ in 0..REPS {
                    black_box(unsafe { avx2::sumsq_i16(black_box(&op.red_a)) });
                }
            })
            .mean_ns;
        speedups.push(("sumsq_i16", s / v.max(1e-9)));
        let s = b
            .bench("sum_i16 scalar", || {
                for _ in 0..REPS {
                    black_box(scalar::sum_i16(black_box(&op.red_a)));
                }
            })
            .mean_ns;
        let v = b
            .bench("sum_i16 avx2", || {
                for _ in 0..REPS {
                    black_box(unsafe { avx2::sum_i16(black_box(&op.red_a)) });
                }
            })
            .mean_ns;
        speedups.push(("sum_i16", s / v.max(1e-9)));
    }

    b.section("squash/softmax staging (x512)");
    {
        let s = b
            .bench("scale_i16_q scalar", || {
                let mut out = [0i16; 16];
                for _ in 0..REPS {
                    scalar::scale_i16_q::<12>(black_box(&op.sq_in), 2048, &mut out);
                    black_box(&mut out);
                }
            })
            .mean_ns;
        let v = b
            .bench("scale_i16_q avx2", || {
                let mut out = [0i16; 16];
                for _ in 0..REPS {
                    unsafe { avx2::scale_i16_q::<12>(black_box(&op.sq_in), 2048, &mut out) };
                    black_box(&mut out);
                }
            })
            .mean_ns;
        speedups.push(("scale_i16_q", s / v.max(1e-9)));
        let s = b
            .bench("max_i16 scalar", || {
                for _ in 0..REPS {
                    black_box(scalar::max_i16(black_box(&op.red_a)));
                }
            })
            .mean_ns;
        let v = b
            .bench("max_i16 avx2", || {
                for _ in 0..REPS {
                    black_box(unsafe { avx2::max_i16(black_box(&op.red_a)) });
                }
            })
            .mean_ns;
        speedups.push(("max_i16", s / v.max(1e-9)));
    }

    b.section("fp32 û-projection axpy (64-wide, x512)");
    {
        let mut acc = op.f32_acc.clone();
        let s = b
            .bench("axpy_f32 scalar", || {
                for _ in 0..REPS {
                    scalar::axpy_f32(&mut acc, 0.5, black_box(&op.f32_w));
                }
            })
            .mean_ns;
        let mut acc = op.f32_acc.clone();
        let v = b
            .bench("axpy_f32 avx2", || {
                for _ in 0..REPS {
                    unsafe { avx2::axpy_f32(&mut acc, 0.5, black_box(&op.f32_w)) };
                }
            })
            .mean_ns;
        speedups.push(("axpy_f32", s / v.max(1e-9)));
        let mut out = vec![0.0f32; op.f32_w.len()];
        let s = b
            .bench("mul_f32 scalar", || {
                for _ in 0..REPS {
                    scalar::mul_f32(black_box(&op.f32_w), 1.5, &mut out);
                    black_box(&mut out);
                }
            })
            .mean_ns;
        let v = b
            .bench("mul_f32 avx2", || {
                for _ in 0..REPS {
                    unsafe { avx2::mul_f32(black_box(&op.f32_w), 1.5, &mut out) };
                    black_box(&mut out);
                }
            })
            .mean_ns;
        speedups.push(("mul_f32", s / v.max(1e-9)));
        // Divide by 1.0: a full-latency IEEE divide per lane whose
        // output equals its input, so the buffer never drifts toward
        // subnormals over thousands of reps.
        let mut buf = op.f32_w.clone();
        let s = b
            .bench("div_in_place_f32 scalar", || {
                for _ in 0..REPS {
                    scalar::div_in_place_f32(black_box(&mut buf), 1.0);
                }
            })
            .mean_ns;
        let v = b
            .bench("div_in_place_f32 avx2", || {
                for _ in 0..REPS {
                    unsafe { avx2::div_in_place_f32(black_box(&mut buf), 1.0) };
                }
            })
            .mean_ns;
        speedups.push(("div_in_place_f32", s / v.max(1e-9)));
    }

    println!("\n== speedups (scalar time / avx2 time) ==");
    let mut log_sum = 0.0f64;
    for (name, x) in &speedups {
        println!("{name:<24} {x:>6.2}x");
        log_sum += x.ln();
    }
    let geomean = (log_sum / speedups.len() as f64).exp();
    println!("{:<24} {geomean:>6.2}x", "geomean");
    assert!(
        geomean >= 2.0,
        "AVX2 kernel geomean speedup {geomean:.2}x is below the 2x gate"
    );
    println!("\nkernel gate ok: geomean {geomean:.2}x >= 2x");
}
