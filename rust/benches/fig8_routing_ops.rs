//! Bench: Fig. 8 — per-operation routing latency, non-optimized vs
//! optimized, plus §III-B's unit-level claims (exp 27→14, div 49→36)
//! and the host cost of the functional fixed-point routing.

use fastcaps::config::{AcceleratorOptions, CapsNetConfig};
use fastcaps::fixed::latency::Op;
use fastcaps::fixed::Q12;
use fastcaps::fpga::pe::PeArray;
use fastcaps::fpga::routing_module::{routing_timing, RoutingGeometry, RoutingHardware};
use fastcaps::routing::fixed::{
    accumulated_routing_q12, dynamic_routing_q12, quantize_coupling, PredictionsQ12, SoftmaxMode,
};
use fastcaps::routing::Predictions;
use fastcaps::util::bench::{report_model, Bencher};
use fastcaps::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();

    b.section("§III-B unit latencies (modeled cycles)");
    report_model("exp baseline (CORDIC)", Op::ExpFull.cycles() as f64, "cycles");
    report_model("exp Taylor (Eq. 2)", Op::ExpTaylor.cycles() as f64, "cycles");
    report_model("div fixed", Op::DivFixed.cycles() as f64, "cycles");
    report_model("div exp/log (Eq. 3)", Op::DivExpLog.cycles() as f64, "cycles");

    b.section("Fig. 8 — routing-step cycles (pruned MNIST, 252 capsules)");
    let cfg = CapsNetConfig::paper_pruned_mnist();
    let pe = PeArray::new(&AcceleratorOptions::optimized());
    let g = RoutingGeometry::from_config(&cfg, cfg.num_primary_caps());
    let base = routing_timing(&g, &RoutingHardware::baseline(), &pe);
    let opt = routing_timing(&g, &RoutingHardware::optimized(), &pe);
    for ((name, bc), (_, oc)) in base.stages().iter().zip(opt.stages().iter()) {
        report_model(&format!("{name} [non-opt]"), *bc as f64, "cycles");
        report_model(&format!("{name} [opt]"), *oc as f64, "cycles");
    }
    report_model("total non-optimized", base.total() as f64, "cycles");
    report_model("total optimized", opt.total() as f64, "cycles");

    b.section("host cost: functional Q4.12 routing (252×10×16, 3 iters)");
    let mut rng = Rng::new(1);
    let u: Vec<f32> = (0..252 * 10 * 16).map(|_| rng.normal_f32(0.0, 0.3)).collect();
    let pred = PredictionsQ12::quantize(&Predictions::new(252, 10, 16, u));
    b.bench("dynamic_routing_q12 baseline softmax", || {
        dynamic_routing_q12(&pred, 3, SoftmaxMode::Baseline).counts
    });
    b.bench("dynamic_routing_q12 taylor softmax", || {
        dynamic_routing_q12(&pred, 3, SoftmaxMode::Taylor).counts
    });
    b.bench("exp_taylor_q12 (1k evals)", || {
        let mut acc = 0i32;
        for i in 0..1000 {
            let x = Q12::from_raw((i % 4096) as i16 - 2048);
            acc += fastcaps::fixed::taylor::exp_taylor_q12(x).raw() as i32;
        }
        acc
    });

    b.section("accumulated-coefficients fast path (zero routing iterations)");
    // Host cost: one weighted sum + squash vs the 3-iteration schedule.
    let coupling = quantize_coupling(&vec![0.1f32; 252 * 10]);
    b.bench("accumulated_routing_q12 (baked coefficients)", || {
        accumulated_routing_q12(&pred, &coupling).counts
    });
    // Modeled cycles: the whole routing module degenerates to the
    // zero-iteration schedule.
    let mut g0 = g;
    g0.iterations = 0;
    let acc_t = routing_timing(&g0, &RoutingHardware::optimized(), &pe);
    report_model("total accumulated (0 iters)", acc_t.total() as f64, "cycles");

    // Regression gate: an Accumulated deployment and one pinned to
    // Iterative(0) must price identically — same routing cycles, same
    // frame cycles, same DDR bytes — and both must undercut the default
    // iterative schedule.
    use fastcaps::config::SystemConfig;
    use fastcaps::fpga::DeployedModel;
    use fastcaps::routing::RoutingMode;
    let sys = SystemConfig::proposed("mnist");
    let n = sys.sparsity.num_primary_caps(&sys.model) * sys.model.num_classes;
    let mut acc_m = DeployedModel::timing_stub(&sys, 7);
    acc_m
        .bake_accumulated(&vec![1.0 / sys.model.num_classes as f32; n])
        .unwrap();
    let mut zero_m = DeployedModel::timing_stub(&sys, 7);
    zero_m.set_routing_mode(RoutingMode::Iterative(0)).unwrap();
    let default_m = DeployedModel::timing_stub(&sys, 7);
    assert_eq!(
        acc_m.ddr_bytes(),
        zero_m.ddr_bytes(),
        "accumulated DDR pricing must equal iterative(0)"
    );
    assert_eq!(
        acc_m.estimate_frame().total_cycles(),
        zero_m.estimate_frame().total_cycles(),
        "accumulated frame cycles must equal iterative(0)"
    );
    assert!(
        acc_m.estimate_frame().total_cycles() < default_m.estimate_frame().total_cycles(),
        "accumulated mode must undercut the default iterative schedule"
    );
}
