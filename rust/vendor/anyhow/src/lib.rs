//! Offline stand-in for the `anyhow` crate, vendored because the build
//! environment has no network access. Implements exactly the subset the
//! fastcaps crate uses: [`Error`], [`Result`], the [`anyhow!`], [`bail!`]
//! and [`ensure!`] macros, and the [`Context`] extension trait for
//! `Result<T, E: std::error::Error>` and `Option<T>`.
//!
//! Display semantics follow upstream: `{}` prints the outermost message,
//! `{:#}` prints the whole cause chain separated by `": "`.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error: an outermost message plus its cause chain.
pub struct Error {
    /// Outermost context first.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an additional layer of context (outermost).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*).into())
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($tt:tt)*) => {
        if !($cond) {
            $crate::bail!($($tt)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = Err::<(), std::io::Error>(io_err())
            .context("opening file")
            .unwrap_err();
        assert_eq!(format!("{e}"), "opening file");
        assert_eq!(format!("{e:#}"), "opening file: gone");
    }

    #[test]
    fn macros_build_errors() {
        let n = 3;
        let e = anyhow!("bad value {n}");
        assert_eq!(format!("{e}"), "bad value 3");
        let e = anyhow!("bad {} of {}", "kind", 7);
        assert_eq!(format!("{e}"), "bad kind of 7");

        fn fails() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(format!("{}", fails().unwrap_err()), "nope 1");

        fn checked(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(checked(1).is_ok());
        assert_eq!(
            format!("{}", checked(-2).unwrap_err()),
            "x must be positive, got -2"
        );
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here/xyz")?;
            Ok(s)
        }
        assert!(read().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
        assert_eq!(Some(5).context("missing").unwrap(), 5);
    }
}
