"""Pruning-method tests, including the paper's Fig. 7 worked example
(shared golden values with the rust test in rust/src/pruning/lakp.rs)."""

import numpy as np
import pytest

from compile import pruning


def kernels_with_sums(vals, k=3):
    """OIHW tensor whose (o,i) kernel has abs-sum vals[o][i]."""
    vals = np.asarray(vals, dtype=np.float32)
    o, i = vals.shape
    w = np.ones((o, i, k, k), dtype=np.float32)
    return w * (vals / (k * k))[:, :, None, None]


class TestFig7Example:
    def test_scores_match_paper(self):
        w_prev = kernels_with_sums([[8, 9], [10, 9]])
        w_i = kernels_with_sums([[8, 8], [9, 10]])
        w_next = kernels_with_sums([[6, 10], [9, 10]])
        prev = pruning.prev_norms_from_conv(w_prev)
        nxt = pruning.next_norms_from_conv(w_next)
        s = pruning.lakp_scores(w_i, prev, nxt)
        # Fig. 7 (with its (0,0) typo corrected: 8·17·15 = 2040, not 2295).
        np.testing.assert_allclose(
            s, [[2040, 2280], [3060, 3800]], rtol=1e-5
        )

    def test_mask_matches_paper(self):
        w_prev = kernels_with_sums([[8, 9], [10, 9]])
        w_i = kernels_with_sums([[8, 8], [9, 10]])
        w_next = kernels_with_sums([[6, 10], [9, 10]])
        s = pruning.lakp_scores(
            w_i,
            pruning.prev_norms_from_conv(w_prev),
            pruning.next_norms_from_conv(w_next),
        )
        mask = pruning.mask_lowest(s, 0.5)
        np.testing.assert_array_equal(mask, [[0, 0], [1, 1]])


class TestMasks:
    @pytest.mark.parametrize("sparsity", [0.0, 0.25, 0.5, 0.9, 1.0])
    def test_sparsity_respected(self, sparsity):
        rng = np.random.default_rng(1)
        w = rng.normal(size=(8, 4, 3, 3)).astype(np.float32)
        mask = pruning.mask_lowest(pruning.kp_scores(w), sparsity)
        expect_pruned = int(np.floor(32 * sparsity))
        assert int(32 - mask.sum()) == expect_pruned

    def test_apply_zeroes_kernels(self):
        rng = np.random.default_rng(2)
        w = rng.normal(size=(4, 4, 3, 3)).astype(np.float32)
        mask = pruning.mask_lowest(pruning.kp_scores(w), 0.5)
        wp = pruning.apply_kernel_mask(w, mask)
        for o in range(4):
            for i in range(4):
                if mask[o, i] == 0:
                    assert np.all(wp[o, i] == 0)
                else:
                    np.testing.assert_array_equal(wp[o, i], w[o, i])

    def test_unstructured_keeps_largest(self):
        w = np.asarray([[0.1, -0.9], [0.5, -0.05]], dtype=np.float32)
        m = pruning.unstructured_mask(w, 0.5)
        np.testing.assert_array_equal(m, [[0, 1], [1, 0]])


class TestLakpVsKp:
    def test_neutral_adjacency_reduces_to_kp(self):
        rng = np.random.default_rng(3)
        w = rng.normal(size=(6, 4, 3, 3)).astype(np.float32)
        ones_prev = np.ones(4, dtype=np.float32)
        ones_next = np.ones(6, dtype=np.float32)
        s_lakp = pruning.lakp_scores(w, ones_prev, ones_next)
        s_kp = pruning.kp_scores(w)
        np.testing.assert_allclose(s_lakp, s_kp, rtol=1e-6)

    def test_adjacency_changes_choice(self):
        w = kernels_with_sums([[5], [5]])
        nxt = np.asarray([0.1, 10.0], dtype=np.float32)
        s = pruning.lakp_scores(w, np.ones(1, np.float32), nxt)
        mask = pruning.mask_lowest(s, 0.5)
        np.testing.assert_array_equal(mask, [[0], [1]])

    def test_capsnet_masks_shapes(self):
        import jax

        from compile.model import CapsConfig, init_params

        cfg = CapsConfig.small()
        params = init_params(cfg, jax.random.PRNGKey(0))
        for method in ("kp", "lakp"):
            masks = pruning.capsnet_masks(params, 0.9, method)
            assert masks["conv1_w"].shape == (cfg.conv1_ch, 1)
            assert masks["pc_w"].shape == (cfg.pc_channels(), cfg.conv1_ch)
            frac = pruning.survived_weight_fraction_capsnet(masks, params)
            assert 0.05 < frac < 0.15  # ~10% survived

    def test_convnet_masks_cover_all_layers(self):
        import jax

        from compile import convnets

        spec = convnets.ConvNetSpec.vgg_small()
        params = convnets.init_params(spec, jax.random.PRNGKey(0))
        masks = pruning.convnet_masks(params, 0.5, "lakp", head_w=params["head_w"])
        assert len(masks) == len(params["convs"])
        for m, w in zip(masks, params["convs"]):
            assert m.shape == w.shape[:2]
