"""L2 model tests: shapes, pallas-vs-ref parity, config presets, loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data
from compile.model import CapsConfig, forward, init_params, margin_loss


@pytest.fixture(scope="module")
def small_setup():
    cfg = CapsConfig.small()
    params = init_params(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(data.generate("digits", 4, seed=3)[0])
    return cfg, params, x


class TestConfigs:
    def test_paper_capsule_counts(self):
        assert CapsConfig.paper_full().num_primary_caps() == 1152
        assert CapsConfig.paper_pruned_mnist().num_primary_caps() == 252
        assert CapsConfig.paper_pruned_fmnist().num_primary_caps() == 432

    def test_spatial_dims(self):
        cfg = CapsConfig.paper_full()
        assert cfg.conv1_out() == (20, 20)
        assert cfg.pc_out() == (6, 6)

    def test_param_shapes_order_matches_fcw(self):
        names = [n for n, _ in CapsConfig.paper_pruned_mnist().param_shapes()]
        assert names == ["conv1_w", "conv1_b", "pc_w", "pc_b", "w_ij"]


class TestForward:
    def test_shapes(self, small_setup):
        cfg, params, x = small_setup
        lengths, v = forward(params, x, cfg, use_pallas=False)
        assert lengths.shape == (4, 10)
        assert v.shape == (4, 10, cfg.dc_dim)

    def test_lengths_are_probability_like(self, small_setup):
        cfg, params, x = small_setup
        lengths, _ = forward(params, x, cfg, use_pallas=False)
        assert bool(jnp.all(lengths >= 0))
        assert bool(jnp.all(lengths < 1.0))

    def test_pallas_matches_ref_path(self, small_setup):
        cfg, params, x = small_setup
        l_pl, v_pl = forward(params, x, cfg, taylor=False, use_pallas=True)
        l_rf, v_rf = forward(params, x, cfg, taylor=False, use_pallas=False)
        np.testing.assert_allclose(l_pl, l_rf, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(v_pl, v_rf, rtol=1e-4, atol=1e-5)

    def test_taylor_does_not_change_prediction(self, small_setup):
        # §IV-B: optimization does not reduce accuracy.
        cfg, params, x = small_setup
        l_t, _ = forward(params, x, cfg, taylor=True, use_pallas=False)
        l_e, _ = forward(params, x, cfg, taylor=False, use_pallas=False)
        assert jnp.argmax(l_t, -1).tolist() == jnp.argmax(l_e, -1).tolist()
        np.testing.assert_allclose(l_t, l_e, atol=2e-3)

    def test_batch_independence(self, small_setup):
        cfg, params, x = small_setup
        l_all, _ = forward(params, x, cfg, use_pallas=False)
        l_one, _ = forward(params, x[:1], cfg, use_pallas=False)
        np.testing.assert_allclose(l_all[:1], l_one, rtol=1e-5, atol=1e-6)


class TestMarginLoss:
    def test_perfect_prediction_low_loss(self):
        lengths = jnp.asarray([[0.95, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05]])
        labels = jnp.asarray([0])
        assert float(margin_loss(lengths, labels)) < 1e-3

    def test_wrong_prediction_high_loss(self):
        lengths = jnp.asarray([[0.05, 0.95, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05]])
        labels = jnp.asarray([0])
        assert float(margin_loss(lengths, labels)) > 0.5

    def test_differentiable(self):
        cfg = CapsConfig.small()
        params = init_params(cfg, jax.random.PRNGKey(1))
        x = jnp.asarray(data.generate("digits", 2, seed=5)[0])
        y = jnp.asarray([0, 1])

        def loss(p):
            lengths, _ = forward(p, x, cfg, taylor=False, use_pallas=False)
            return margin_loss(lengths, y)

        g = jax.grad(loss)(params)
        gnorm = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree_util.tree_leaves(g))
        assert np.isfinite(gnorm) and gnorm > 0


class TestData:
    @pytest.mark.parametrize("task,shape", [
        ("digits", (1, 28, 28)), ("garments", (1, 28, 28)),
        ("blobs32", (3, 32, 32)), ("signs32", (3, 32, 32)),
    ])
    def test_shapes_and_range(self, task, shape):
        xs, ys = data.generate(task, 20, seed=1)
        assert xs.shape == (20, *shape)
        assert xs.min() >= 0.0 and xs.max() <= 1.0
        assert set(ys.tolist()) == set(range(10))

    def test_deterministic(self):
        a, _ = data.generate("digits", 5, seed=9)
        b, _ = data.generate("digits", 5, seed=9)
        np.testing.assert_array_equal(a, b)

    def test_classes_differ(self):
        xs, ys = data.generate("digits", 20, seed=2)
        d01 = np.abs(xs[0] - xs[1]).sum()  # class 0 vs 1
        assert d01 > 5.0
