"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and value ranges — the CORE correctness signal
for the compute layer the rust runtime ends up executing.
"""

import jax.numpy as jnp
import numpy as np
import pytest

# The property sweeps need hypothesis; skip the module (with a reason,
# not a collection error) in environments without it. CI installs it.
pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref, routing, softmax_taylor, squash

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(shape, seed, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32) * scale
    )


class TestMatmul:
    @given(
        m=st.integers(1, 96),
        k=st.integers(1, 96),
        n=st.integers(1, 96),
        seed=st.integers(0, 2**31),
    )
    def test_matches_jnp(self, m, k, n, seed):
        x = rand((m, k), seed)
        y = rand((k, n), seed + 1)
        got = matmul.matmul(x, y)
        np.testing.assert_allclose(got, x @ y, rtol=1e-4, atol=1e-4)

    def test_paper_conv_shapes(self):
        # PrimaryCaps pruned-MNIST im2col: [36, 5184] @ [5184, 56].
        x = rand((36, 5184), 1, 0.1)
        y = rand((5184, 56), 2, 0.1)
        np.testing.assert_allclose(
            matmul.matmul(x, y), x @ y, rtol=1e-3, atol=1e-3
        )

    def test_block_picking(self):
        assert matmul.pick_block(36, 128) == 36
        assert matmul.pick_block(400, 128) == 100
        assert matmul.pick_block(5184, 512) == 432
        assert matmul.pick_block(7, 4) == 1

    @given(
        c=st.integers(1, 8),
        o=st.integers(1, 8),
        hw=st.integers(5, 12),
        k=st.sampled_from([3, 5]),
        stride=st.sampled_from([1, 2]),
        seed=st.integers(0, 2**31),
    )
    def test_conv2d_vs_ref(self, c, o, hw, k, stride, seed):
        x = rand((c, hw, hw), seed, 0.5)
        w = rand((o, c, k, k), seed + 1, 0.2)
        b = rand((o,), seed + 2)
        got = matmul.conv2d(x, w, b, stride=stride)
        want = ref.conv2d(x, w, b, stride=stride)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_ref_conv_vs_lax(self):
        # Anchor the oracle itself against lax.conv.
        from jax import lax

        x = rand((4, 14, 14), 3, 0.5)
        w = rand((6, 4, 5, 5), 4, 0.2)
        want = lax.conv_general_dilated(
            x[None], w, (2, 2), "VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )[0]
        got = ref.conv2d(x, w, stride=2)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestSquash:
    @given(
        n=st.integers(1, 300),
        d=st.integers(1, 32),
        seed=st.integers(0, 2**31),
        scale=st.floats(0.01, 10.0),
    )
    def test_matches_ref(self, n, d, seed, scale):
        x = rand((n, d), seed, scale)
        np.testing.assert_allclose(
            squash.squash(x), ref.squash(x), rtol=1e-4, atol=1e-5
        )

    def test_norm_below_one(self):
        x = rand((64, 8), 5, 20.0)
        v = squash.squash(x)
        norms = jnp.linalg.norm(v, axis=-1)
        assert float(jnp.max(norms)) < 1.0

    def test_zero_is_safe(self):
        v = squash.squash(jnp.zeros((4, 8)))
        assert bool(jnp.all(jnp.isfinite(v)))
        np.testing.assert_allclose(v, 0.0, atol=1e-4)


class TestSoftmaxTaylor:
    @given(
        n=st.integers(1, 300),
        j=st.integers(2, 16),
        seed=st.integers(0, 2**31),
        scale=st.floats(0.1, 4.0),
    )
    def test_matches_ref_taylor(self, n, j, seed, scale):
        b = rand((n, j), seed, scale)
        got = softmax_taylor.softmax_taylor(b)
        np.testing.assert_allclose(got, ref.softmax_taylor(b), rtol=1e-5, atol=1e-6)

    @given(seed=st.integers(0, 2**31))
    def test_close_to_exact_softmax(self, seed):
        # The paper's claim: Taylor form does not change accuracy.
        b = rand((128, 10), seed, 2.0)
        got = softmax_taylor.softmax_taylor(b)
        exact = ref.softmax(b)
        np.testing.assert_allclose(got, exact, atol=2e-4)

    def test_rows_sum_to_one(self):
        b = rand((252, 10), 7, 3.0)
        s = jnp.sum(softmax_taylor.softmax_taylor(b), axis=-1)
        np.testing.assert_allclose(s, 1.0, atol=1e-4)

    def test_taylor_exp_window(self):
        # Eq. 2 accuracy on [0, 1]: < 0.2% relative error.
        x = jnp.linspace(0.0, 1.0, 101)
        rel = jnp.abs(ref.exp_taylor(x) - jnp.exp(x)) / jnp.exp(x)
        assert float(jnp.max(rel)) < 2e-3


class TestRouting:
    @given(
        n=st.integers(2, 64),
        j=st.integers(2, 12),
        d=st.integers(2, 16),
        iters=st.integers(1, 4),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref(self, n, j, d, iters, seed):
        u = rand((n, j, d), seed, 0.4)
        v_pl, c_pl = routing.dynamic_routing(u, iters, taylor=False)
        v_ref, c_ref = ref.dynamic_routing(u, iters, taylor=False)
        np.testing.assert_allclose(v_pl, v_ref, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(c_pl, c_ref, rtol=1e-4, atol=1e-5)

    def test_taylor_matches_exact_routing(self):
        u = rand((252, 10, 16), 11, 0.3)
        v_t, _ = routing.dynamic_routing(u, 3, taylor=True)
        v_e, _ = ref.dynamic_routing(u, 3, taylor=False)
        np.testing.assert_allclose(v_t, v_e, atol=5e-4)

    def test_coupling_uniform_first_iteration(self):
        u = rand((36, 10, 16), 13, 0.4)
        _, c = routing.dynamic_routing(u, 1, taylor=False)
        np.testing.assert_allclose(c, 0.1, atol=1e-5)

    def test_agreement_sharpens_coupling(self):
        # Make all capsules agree on class 0.
        n, j, d = 32, 4, 8
        base = rand((d,), 17, 1.0)
        u = jnp.zeros((n, j, d)).at[:, 0, :].set(base)
        u = u + rand((n, j, d), 19, 0.05)
        _, c1 = routing.dynamic_routing(u, 1)
        _, c3 = routing.dynamic_routing(u, 3)
        assert float(jnp.mean(c3[:, 0])) > float(jnp.mean(c1[:, 0])) + 0.05
