"""The pruning study: regenerates Table I and Fig. 5.

For each (model, dataset) row of Table I: train the scaled model, then
for each sparsity × {KP, LAKP}: prune → fine-tune → measure test error.
Fig. 5 additionally sweeps unstructured magnitude pruning on
CapsNet/digits.

Writes `artifacts/table1.json` and `artifacts/fig5.json`, which the rust
CLI formats (`fastcaps report table1|fig5`).

Usage:
  python -m compile.prune_study [--fast] [--out-dir ../artifacts]

`--fast` trims to 3 sparsities, smaller datasets and fewer epochs
(minutes instead of ~half an hour); the JSON schema is identical.
"""

import argparse
import json
import os
import time

import numpy as np

from . import convnets, pruning, train
from .model import CapsConfig


def finetune_and_eval_capsnet(cfg, task, params, masks, *, epochs, n_train, n_test, seed):
    mask_fn = pruning.capsnet_mask_fn(masks)
    pruned = mask_fn(params)
    tuned, err, _ = train.train_capsnet(
        cfg, task, params=pruned, mask_fn=mask_fn, epochs=epochs,
        n_train=n_train, n_test=n_test, seed=seed, lr=5e-4,
        log=lambda *_: None,
    )
    del tuned
    return err


def finetune_and_eval_convnet(spec, task, params, masks, *, epochs, n_train, n_test, seed):
    mask_fn = pruning.convnet_mask_fn(masks)
    pruned = mask_fn(params)
    tuned, err, _ = train.train_convnet(
        spec, task, params=pruned, mask_fn=mask_fn, epochs=epochs,
        n_train=n_train, n_test=n_test, seed=seed, lr=5e-4,
        log=lambda *_: None,
    )
    del tuned
    return err


def run_table1(fast: bool, log=print):
    sparsities = [0.75, 0.9, 0.97] if fast else [0.5, 0.75, 0.9, 0.97, 0.99]
    n_train = 600 if fast else 1500
    n_test = 300 if fast else 500
    epochs = 2 if fast else 4
    ft_epochs = 1 if fast else 2
    rows = []

    combos = [
        ("capsnet", "digits"), ("capsnet", "garments"),
        ("vgg", "blobs32"), ("vgg", "signs32"),
        ("resnet", "blobs32"), ("resnet", "signs32"),
    ]
    for model_name, task in combos:
        t0 = time.time()
        log(f"== Table I row: {model_name} / {task} ==")
        if model_name == "capsnet":
            cfg = CapsConfig.small()
            params, base_err, _ = train.train_capsnet(
                cfg, task, epochs=epochs, n_train=n_train, n_test=n_test,
                seed=1, log=log,
            )
            for s in sparsities:
                row = {"model": model_name, "dataset": task,
                       "actual_error": base_err, "sparsity": s}
                for method in ("kp", "lakp"):
                    masks = pruning.capsnet_masks(params, s, method)
                    row[f"survived_{method}"] = \
                        pruning.survived_weight_fraction_capsnet(masks, params)
                    row[f"error_{method}"] = finetune_and_eval_capsnet(
                        cfg, task, params, masks, epochs=ft_epochs,
                        n_train=n_train, n_test=n_test, seed=2,
                    )
                log(f"  s={s:.2f}: KP {row['error_kp']:.2f}% "
                    f"LAKP {row['error_lakp']:.2f}%")
                rows.append(row)
        else:
            spec = (convnets.ConvNetSpec.vgg_small() if model_name == "vgg"
                    else convnets.ConvNetSpec.resnet_small())
            # Conv nets are cheap to train — give them enough epochs to
            # leave the chance plateau even in --fast mode.
            params, base_err, _ = train.train_convnet(
                spec, task, epochs=max(epochs, 6), n_train=n_train,
                n_test=n_test, seed=1, log=log,
            )
            for s in sparsities:
                row = {"model": model_name, "dataset": task,
                       "actual_error": base_err, "sparsity": s}
                for method in ("kp", "lakp"):
                    masks = pruning.convnet_masks(
                        params, s, method, head_w=params["head_w"]
                    )
                    row[f"survived_{method}"] = \
                        pruning.survived_weight_fraction_convnet(masks, params)
                    row[f"error_{method}"] = finetune_and_eval_convnet(
                        spec, task, params, masks, epochs=ft_epochs,
                        n_train=n_train, n_test=n_test, seed=2,
                    )
                log(f"  s={s:.2f}: KP {row['error_kp']:.2f}% "
                    f"LAKP {row['error_lakp']:.2f}%")
                rows.append(row)
        log(f"  row done in {time.time() - t0:.0f}s")
    return {"experiment": "table1", "rows": rows}


def run_fig5(fast: bool, log=print):
    """Fig. 5: LAKP vs KP vs unstructured magnitude on CapsNet/digits."""
    sparsities = [0.5, 0.9, 0.99] if fast else [0.5, 0.75, 0.9, 0.97, 0.99, 0.995]
    n_train = 600 if fast else 1500
    n_test = 300 if fast else 500
    epochs = 2 if fast else 4
    ft_epochs = 1 if fast else 2
    cfg = CapsConfig.small()
    log("== Fig. 5 sweep: CapsNet / digits ==")
    params, base_err, _ = train.train_capsnet(
        cfg, "digits", epochs=epochs, n_train=n_train, n_test=n_test,
        seed=1, log=log,
    )
    points = []
    for s in sparsities:
        pt = {"sparsity": s}
        for method in ("kp", "lakp"):
            masks = pruning.capsnet_masks(params, s, method)
            pt[f"survived_{method}"] = \
                pruning.survived_weight_fraction_capsnet(masks, params)
            pt[f"error_{method}"] = finetune_and_eval_capsnet(
                cfg, "digits", params, masks, epochs=ft_epochs,
                n_train=n_train, n_test=n_test, seed=2,
            )
        # Unstructured magnitude at matched *weight* sparsity.
        import jax.numpy as jnp

        m1 = pruning.unstructured_mask(np.asarray(params["conv1_w"]), s)
        m2 = pruning.unstructured_mask(np.asarray(params["pc_w"]), s)
        jm1, jm2 = jnp.asarray(m1), jnp.asarray(m2)

        def mask_fn(p, jm1=jm1, jm2=jm2):
            p = dict(p)
            p["conv1_w"] = p["conv1_w"] * jm1
            p["pc_w"] = p["pc_w"] * jm2
            return p

        tuned, err, _ = train.train_capsnet(
            cfg, "digits", params=mask_fn(params), mask_fn=mask_fn,
            epochs=ft_epochs, n_train=n_train, n_test=n_test, seed=2,
            lr=5e-4, log=lambda *_: None,
        )
        del tuned
        pt["survived_unstructured"] = float((m1.sum() + m2.sum()) /
                                            (m1.size + m2.size))
        pt["error_unstructured"] = err
        log(f"  s={s}: KP {pt['error_kp']:.2f} LAKP {pt['error_lakp']:.2f} "
            f"unstr {pt['error_unstructured']:.2f}")
        points.append(pt)
    return {"experiment": "fig5", "baseline_error": base_err, "points": points}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", choices=["table1", "fig5"], default=None)
    args = ap.parse_args(argv)
    os.makedirs(args.out_dir, exist_ok=True)
    if args.only in (None, "table1"):
        t1 = run_table1(args.fast)
        with open(os.path.join(args.out_dir, "table1.json"), "w") as f:
            json.dump(t1, f, indent=2, sort_keys=True)
        print(f"wrote table1.json ({len(t1['rows'])} rows)")
    if args.only in (None, "fig5"):
        f5 = run_fig5(args.fast)
        with open(os.path.join(args.out_dir, "fig5.json"), "w") as f:
            json.dump(f5, f, indent=2, sort_keys=True)
        print(f"wrote fig5.json ({len(f5['points'])} points)")


if __name__ == "__main__":
    main()
