"""Training loops (build-time only) for the pruning study.

The paper trains CapsNet / VGG-19 / ResNet-18 on Colab GPUs; this module
trains the scaled counterparts (DESIGN.md §4) on CPU JAX. Hand-rolled
Adam (no optax in the environment); the CapsNet path trains through the
pure-jnp reference kernels (differentiable and ~10× faster to trace than
interpret-mode Pallas — the Pallas path is the *inference* artifact).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import convnets, data
from .kernels import ref
from .model import CapsConfig, forward, init_params, margin_loss


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree_util.tree_map(lambda x: x / (1 - b1**t), m)
    vhat = jax.tree_util.tree_map(lambda x: x / (1 - b2**t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return new, {"m": m, "v": v, "t": t}


def _batches(n, batch, rng):
    idx = rng.permutation(n)
    for i in range(0, n - batch + 1, batch):
        yield idx[i : i + batch]


def train_capsnet(
    cfg: CapsConfig,
    task: str,
    *,
    n_train=1500,
    n_test=500,
    epochs=4,
    batch=32,
    lr=2e-3,
    seed=0,
    mask_fn=None,
    params=None,
    log=print,
):
    """Train (or fine-tune, if `params`/`mask_fn` given) a CapsNet.

    `mask_fn(params) -> params` re-applies pruning masks after each step.
    Returns (params, test_error_percent, history)."""
    xs, ys = data.generate(task, n_train + n_test, seed=seed)
    xtr, ytr = jnp.asarray(xs[:n_train]), jnp.asarray(ys[:n_train])
    xte, yte = jnp.asarray(xs[n_train:]), jnp.asarray(ys[n_train:])
    if params is None:
        params = init_params(cfg, jax.random.PRNGKey(seed))

    def loss_fn(p, xb, yb):
        lengths, _ = forward(p, xb, cfg, taylor=False, use_pallas=False)
        return margin_loss(lengths, yb, cfg.num_classes)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    @jax.jit
    def eval_batch(p, xb):
        lengths, _ = forward(p, xb, cfg, taylor=False, use_pallas=False)
        return jnp.argmax(lengths, axis=-1)

    opt = adam_init(params)
    nprng = np.random.default_rng(seed)
    history = []
    t0 = time.time()
    for epoch in range(epochs):
        losses = []
        for idx in _batches(n_train, batch, nprng):
            loss, grads = grad_fn(params, xtr[idx], ytr[idx])
            params, opt = adam_step(params, grads, opt, lr=lr)
            if mask_fn is not None:
                params = mask_fn(params)
            losses.append(float(loss))
        history.append(float(np.mean(losses)))
        log(f"  [{cfg.name}/{task}] epoch {epoch}: loss {history[-1]:.4f} "
            f"({time.time() - t0:.0f}s)")
    err = test_error_capsnet(params, cfg, xte, yte, eval_batch=eval_batch)
    return params, err, history


def test_error_capsnet(params, cfg, xte, yte, *, eval_batch=None, batch=100):
    if eval_batch is None:
        @jax.jit
        def eval_batch(p, xb):
            lengths, _ = forward(p, xb, cfg, taylor=False, use_pallas=False)
            return jnp.argmax(lengths, axis=-1)

    wrong = 0
    n = xte.shape[0]
    for i in range(0, n, batch):
        pred = eval_batch(params, xte[i : i + batch])
        wrong += int(jnp.sum(pred != yte[i : i + batch]))
    return 100.0 * wrong / n


def train_convnet(
    spec: convnets.ConvNetSpec,
    task: str,
    *,
    n_train=2000,
    n_test=500,
    epochs=4,
    batch=64,
    lr=2e-3,
    seed=0,
    mask_fn=None,
    params=None,
    log=print,
):
    """Train/fine-tune a VGG-small or ResNet-small classifier."""
    xs, ys = data.generate(task, n_train + n_test, seed=seed)
    xtr, ytr = jnp.asarray(xs[:n_train]), jnp.asarray(ys[:n_train])
    xte, yte = jnp.asarray(xs[n_train:]), jnp.asarray(ys[n_train:])
    if params is None:
        params = convnets.init_params(spec, jax.random.PRNGKey(seed))

    def loss_fn(p, xb, yb):
        return convnets.cross_entropy(convnets.forward(p, xb, spec), yb)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    @jax.jit
    def eval_batch(p, xb):
        return jnp.argmax(convnets.forward(p, xb, spec), axis=-1)

    opt = adam_init(params)
    nprng = np.random.default_rng(seed)
    history = []
    for epoch in range(epochs):
        losses = []
        for idx in _batches(n_train, batch, nprng):
            loss, grads = grad_fn(params, xtr[idx], ytr[idx])
            params, opt = adam_step(params, grads, opt, lr=lr)
            if mask_fn is not None:
                params = mask_fn(params)
            losses.append(float(loss))
        history.append(float(np.mean(losses)))
        log(f"  [{spec.name}/{task}] epoch {epoch}: loss {history[-1]:.4f}")
    err = test_error_convnet(params, spec, xte, yte, eval_batch=eval_batch)
    return params, err, history


def test_error_convnet(params, spec, xte, yte, *, eval_batch=None, batch=100):
    if eval_batch is None:
        @jax.jit
        def eval_batch(p, xb):
            return jnp.argmax(convnets.forward(p, xb, spec), axis=-1)

    wrong = 0
    n = xte.shape[0]
    for i in range(0, n, batch):
        pred = eval_batch(params, xte[i : i + batch])
        wrong += int(jnp.sum(pred != yte[i : i + batch]))
    return 100.0 * wrong / n
