"""L2: the CapsNet forward graph in JAX, composing the L1 Pallas kernels.

Mirrors `rust/src/capsnet` (same architecture presets, same shared
DigitCaps transform, same `.fcw` weight order) so the HLO the rust
runtime executes and the fp32 rust reference agree. The forward is built
once per (config, batch) by `aot.py` and never runs in production Python.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import matmul as k_matmul
from .kernels import ref
from .kernels import routing as k_routing
from .kernels import squash as k_squash


@dataclass(frozen=True)
class CapsConfig:
    """Architecture preset — mirrors rust `config::CapsNetConfig`."""

    name: str
    input: tuple  # (C, H, W)
    conv1_ch: int
    conv1_k: int
    conv1_stride: int
    pc_types: int
    pc_dim: int
    pc_k: int
    pc_stride: int
    num_classes: int
    dc_dim: int
    routing_iters: int

    @staticmethod
    def paper_full(name="capsnet-mnist"):
        return CapsConfig(name, (1, 28, 28), 256, 9, 1, 32, 8, 9, 2, 10, 16, 3)

    @staticmethod
    def paper_pruned_mnist():
        c = CapsConfig.paper_full("capsnet-mnist-pruned")
        return CapsConfig(**{**c.__dict__, "name": "capsnet-mnist-pruned",
                             "conv1_ch": 64, "pc_types": 7})

    @staticmethod
    def paper_pruned_fmnist():
        c = CapsConfig.paper_full("capsnet-fmnist-pruned")
        return CapsConfig(**{**c.__dict__, "name": "capsnet-fmnist-pruned",
                             "conv1_ch": 96, "pc_types": 12})

    @staticmethod
    def small(name="capsnet-small"):
        """Training-scale variant for the Table I pruning study."""
        return CapsConfig(name, (1, 28, 28), 32, 9, 1, 8, 8, 9, 2, 10, 16, 3)

    def conv1_out(self):
        _, h, w = self.input
        return ((h - self.conv1_k) // self.conv1_stride + 1,
                (w - self.conv1_k) // self.conv1_stride + 1)

    def pc_out(self):
        h, w = self.conv1_out()
        return ((h - self.pc_k) // self.pc_stride + 1,
                (w - self.pc_k) // self.pc_stride + 1)

    def pc_channels(self):
        return self.pc_types * self.pc_dim

    def num_primary_caps(self):
        h, w = self.pc_out()
        return self.pc_types * h * w

    def param_shapes(self):
        """Ordered (name, shape) list — the `.fcw` interchange order."""
        c_in = self.input[0]
        return [
            ("conv1_w", (self.conv1_ch, c_in, self.conv1_k, self.conv1_k)),
            ("conv1_b", (self.conv1_ch,)),
            ("pc_w", (self.pc_channels(), self.conv1_ch, self.pc_k, self.pc_k)),
            ("pc_b", (self.pc_channels(),)),
            ("w_ij", (self.pc_types, self.num_classes, self.pc_dim, self.dc_dim)),
        ]


def init_params(cfg: CapsConfig, key):
    """He-normal init matching rust `Weights::random`."""
    ks = jax.random.split(key, 3)
    c_in = self_in = cfg.input[0]
    shapes = dict(cfg.param_shapes())
    std1 = (2.0 / (self_in * cfg.conv1_k**2)) ** 0.5
    std2 = (2.0 / (cfg.conv1_ch * cfg.pc_k**2)) ** 0.5
    # Small transform init keeps initial capsule lengths in the sensitive
    # region of the margin loss (all-lengths≈1 is a flat plateau).
    std3 = 0.5 / cfg.pc_dim
    del c_in
    return {
        "conv1_w": std1 * jax.random.normal(ks[0], shapes["conv1_w"]),
        "conv1_b": jnp.zeros(shapes["conv1_b"]),
        "pc_w": std2 * jax.random.normal(ks[1], shapes["pc_w"]),
        "pc_b": jnp.zeros(shapes["pc_b"]),
        "w_ij": std3 * jax.random.normal(ks[2], shapes["w_ij"]),
    }


def _forward_single(params, x, cfg: CapsConfig, *, taylor: bool, use_pallas: bool):
    """One image `[C,H,W]` → (lengths [J], v [J,D])."""
    conv = k_matmul.conv2d if use_pallas else ref.conv2d
    a1 = jax.nn.relu(
        conv(x, params["conv1_w"], params["conv1_b"], stride=cfg.conv1_stride)
    )
    pc = conv(a1, params["pc_w"], params["pc_b"], stride=cfg.pc_stride)
    h2, w2 = cfg.pc_out()
    # [T*D, h2, w2] -> capsules [T, h2*w2, D] -> [N, D].
    caps = pc.reshape(cfg.pc_types, cfg.pc_dim, h2 * w2).transpose(0, 2, 1)
    u = caps.reshape(cfg.num_primary_caps(), cfg.pc_dim)
    u = k_squash.squash(u) if use_pallas else ref.squash(u)
    # Shared transform per type: û[t,s,j,e] = Σ_d u[t,s,d]·W[t,j,d,e].
    u_t = u.reshape(cfg.pc_types, h2 * w2, cfg.pc_dim)
    u_hat = jnp.einsum("tsd,tjde->tsje", u_t, params["w_ij"])
    u_hat = u_hat.reshape(cfg.num_primary_caps(), cfg.num_classes, cfg.dc_dim)
    if use_pallas:
        v, _ = k_routing.dynamic_routing(u_hat, cfg.routing_iters, taylor=taylor)
    else:
        v, _ = ref.dynamic_routing(u_hat, cfg.routing_iters, taylor=taylor)
    return ref.capsule_lengths(v), v


def forward(params, x, cfg: CapsConfig, *, taylor: bool = True,
            use_pallas: bool = True, batch_mode: str = "vmap"):
    """Batched forward: x `[B,C,H,W]` → (lengths [B,J], v [B,J,D]).

    `batch_mode="map"` lowers the batch as `lax.map` instead of `vmap` —
    3.8× faster for the interpret-mode Pallas path on CPU PJRT (vmap turns
    the kernels' grid loops into batched while-loops XLA executes poorly;
    see EXPERIMENTS.md §Perf). The AOT artifacts use "map"; training and
    tests keep "vmap" (differentiation-friendly, fuses with the ref path).
    """
    f = lambda img: _forward_single(
        params, img, cfg, taylor=taylor, use_pallas=use_pallas
    )
    if batch_mode == "map":
        return jax.lax.map(f, x)
    return jax.vmap(f)(x)


def margin_loss(lengths, labels, num_classes=10, m_pos=0.9, m_neg=0.1, lam=0.5):
    """CapsNet margin loss (Sabour et al. Eq. 4)."""
    t = jax.nn.one_hot(labels, num_classes)
    pos = t * jnp.maximum(0.0, m_pos - lengths) ** 2
    neg = lam * (1.0 - t) * jnp.maximum(0.0, lengths - m_neg) ** 2
    return jnp.mean(jnp.sum(pos + neg, axis=-1))
