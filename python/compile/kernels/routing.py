"""Pallas dynamic-routing kernels — one routing iteration as two kernels.

The iteration splits exactly where the paper's loop reorder (Code 1 →
Code 2) splits the hardware schedule:

1. [`coupling_sum`] — per capsule-block: Taylor-softmax the logits, then
   accumulate the partial weighted sum `s[j,d] += Σ_n c[n,j]·û[n,j,d]`
   across grid steps (the FC step; the output block is revisited by every
   grid step, the Pallas image of the reorder that keeps `s` resident).
2. [`agreement`] — per capsule-block: `b[n,j] += Σ_d û[n,j,d]·v[j,d]`,
   embarrassingly parallel after the reorder (no write conflicts — each
   grid step owns its `b` rows, unlike Code 1's `b[i][j] +=` inner loop).

The squash between the two runs on the squash kernel. All shapes are
blocked over N (capsules) so a û tile stays in VMEM per step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block
from .softmax_taylor import _exp_taylor


def _coupling_sum_kernel(taylor: bool, b_ref, u_ref, c_ref, s_ref):
    b = b_ref[...]
    m = jnp.max(b, axis=-1, keepdims=True)
    if taylor:
        e = _exp_taylor(b - m)
        s = jnp.sum(e, axis=-1, keepdims=True)
        c = _exp_taylor(jnp.log(e + 1e-9) - jnp.log(s))
    else:
        e = jnp.exp(b - m)
        c = e / jnp.sum(e, axis=-1, keepdims=True)
    c_ref[...] = c

    @pl.when(pl.program_id(0) == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    s_ref[...] += jnp.einsum("nj,njd->jd", c, u_ref[...])


@functools.partial(jax.jit, static_argnames=("taylor", "block"))
def coupling_sum(b, u_hat, *, taylor: bool = True, block: int = 128):
    """Softmax + FC step: returns (c [N,J], s [J,D])."""
    n, j = b.shape
    n2, j2, d = u_hat.shape
    assert (n, j) == (n2, j2)
    bn = pick_block(n, block)
    kernel = functools.partial(_coupling_sum_kernel, taylor)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, j), lambda i: (i, 0)),
            pl.BlockSpec((bn, j, d), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, j), lambda i: (i, 0)),
            pl.BlockSpec((j, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, j), b.dtype),
            jax.ShapeDtypeStruct((j, d), b.dtype),
        ],
        interpret=True,
    )(b, u_hat)


def _agreement_kernel(b_ref, u_ref, v_ref, o_ref):
    o_ref[...] = b_ref[...] + jnp.einsum("njd,jd->nj", u_ref[...], v_ref[...])


@functools.partial(jax.jit, static_argnames=("block",))
def agreement(b, u_hat, v, *, block: int = 128):
    """Agreement step (Code 2 order): b' = b + û·v."""
    n, j = b.shape
    _, _, d = u_hat.shape
    bn = pick_block(n, block)
    return pl.pallas_call(
        _agreement_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, j), lambda i: (i, 0)),
            pl.BlockSpec((bn, j, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((j, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, j), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, j), b.dtype),
        interpret=True,
    )(b, u_hat, v)


def dynamic_routing(u_hat, iterations: int = 3, *, taylor: bool = True):
    """Full routing loop on the Pallas kernels. Returns (v [J,D], c [N,J])."""
    from .squash import squash

    n, j, d = u_hat.shape
    b = jnp.zeros((n, j), dtype=u_hat.dtype)
    v = None
    c = None
    for it in range(iterations):
        c, s = coupling_sum(b, u_hat, taylor=taylor)
        v = squash(s)
        if it + 1 < iterations:
            b = agreement(b, u_hat, v)
    return v, c
