"""Blocked Pallas matmul — the conv hot-spot as an im2col contraction.

TPU adaptation of the paper's PE array (DESIGN.md §Hardware-Adaptation):
the 10×9-MAC adder-tree array becomes an MXU-tiled matmul. BlockSpec
plays the role the paper's Index Control Module + BRAM banking plays:
it expresses which (M, N, K) tile is resident in VMEM at each grid step.

Block sizes are the largest divisors of each dim under the caps
(MXU-aligned 128 where the dims allow), so the kernel handles the odd
shapes CapsNet produces (M = 36 output positions, N = 56 channels)
without padding.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block(dim: int, cap: int) -> int:
    """Largest divisor of `dim` that is ≤ cap."""
    best = 1
    for d in range(1, min(dim, cap) + 1):
        if dim % d == 0:
            best = d
    return best


def _matmul_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm: int = 128, bn: int = 128, bk: int = 512):
    """`[M,K] @ [K,N] -> [M,N]` with VMEM-tiled accumulation."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def conv2d(x, w, b=None, stride=1):
    """Valid conv via the Pallas matmul: x [C,H,W], w [O,I,k,k]."""
    from . import ref

    o, i, k, _ = w.shape
    _, h, ww = x.shape
    oh = (h - k) // stride + 1
    ow = (ww - k) // stride + 1
    cols = ref.im2col(x, k, stride)  # [P, I*k*k]
    wmat = w.reshape(o, i * k * k).T  # [I*k*k, O]
    out = matmul(cols, wmat)  # [P, O]
    if b is not None:
        out = out + b[None, :]
    return out.T.reshape(o, oh, ow)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """VMEM footprint of one grid step (x tile + y tile + out tile) —
    used by the §Perf analysis in EXPERIMENTS.md."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)
