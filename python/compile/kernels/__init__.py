# L1: Pallas kernels for the paper's compute hot-spots, plus the pure-jnp
# oracle (ref.py) they are pytest-pinned to. All kernels run with
# interpret=True: the CPU PJRT plugin cannot execute Mosaic custom-calls,
# so interpret mode is both the correctness path and what the AOT bridge
# lowers into the HLO the rust runtime executes (see DESIGN.md §3).
from . import matmul, ref, routing, softmax_taylor, squash  # noqa: F401
