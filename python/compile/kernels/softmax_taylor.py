"""Pallas Taylor-softmax kernel — the paper's §III-B softmax.

Evaluates Eq. 2 (5-term Horner exp about a = 0.5) and Eq. 3
(`a/b = e^(log a − log b)`) over row blocks: multiply/add only in the
polynomial, matching the hardware unit built from the PE array. The
integer range reduction (`e^n` ROM) appears as `jnp.exp(floor(x))`,
which XLA folds to an exp on an integer grid — the software image of
the 64-entry ROM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block
from .ref import E_HALF, EXP_COEFFS


def _exp_taylor(x):
    n = jnp.floor(x)
    f = x - n
    c = [ci * E_HALF for ci in EXP_COEFFS]
    poly = c[0] + f * (c[1] + f * (c[2] + f * (c[3] + f * (c[4] + f * c[5]))))
    return poly * jnp.exp(n)


def _softmax_taylor_kernel(b_ref, o_ref):
    b = b_ref[...]
    m = jnp.max(b, axis=-1, keepdims=True)
    e = _exp_taylor(b - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    # Eq. 3 divider.
    o_ref[...] = _exp_taylor(jnp.log(e + 1e-9) - jnp.log(s))


@functools.partial(jax.jit, static_argnames=("block",))
def softmax_taylor(b, *, block: int = 256):
    """Row softmax of `[N, J]` logits with the Eq. 2/3 datapath."""
    n, j = b.shape
    bn = pick_block(n, block)
    return pl.pallas_call(
        _softmax_taylor_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, j), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, j), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, j), b.dtype),
        interpret=True,
    )(b)
