"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Mirrors the rust f32 reference (`rust/src/routing/mod.rs`) exactly:
squash, softmax (standard and the paper's Eq. 2/3 Taylor form), the
dynamic routing loop, and the im2col convolution the conv kernel
implements. Every Pallas kernel in this package is pytest-pinned to the
function of the same name here.
"""

import jax.numpy as jnp

# Paper Eq. 2: Taylor coefficients of e^x about a = 0.5 (e^a not folded).
EXP_COEFFS = (0.60653, 0.60659, 0.30260, 0.10347, 0.02118, 0.00833)
E_HALF = 1.6487212707


def exp_taylor(x):
    """Eq. 2 exponential: 5-term Horner polynomial on the fractional part,
    power-of-e ROM for the integer part (mul/add only — the form the
    hardware unit evaluates)."""
    n = jnp.floor(x)
    f = x - n
    c = [ci * E_HALF for ci in EXP_COEFFS]
    poly = c[0] + f * (c[1] + f * (c[2] + f * (c[3] + f * (c[4] + f * c[5]))))
    return poly * jnp.exp(n)  # jnp.exp of an integer == ROM lookup


def softmax(b, axis=-1):
    """Max-shifted softmax (standard exp/div)."""
    m = jnp.max(b, axis=axis, keepdims=True)
    e = jnp.exp(b - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def softmax_taylor(b, axis=-1):
    """The paper's optimized softmax: Eq. 2 exp + Eq. 3 divider
    (a/b = e^(log a − log b))."""
    m = jnp.max(b, axis=axis, keepdims=True)
    e = exp_taylor(b - m)
    s = jnp.sum(e, axis=axis, keepdims=True)
    # Eq. 3 with exact log (the hardware log unit's normalization is
    # exact in the exponent and 2e-4-accurate in the mantissa).
    return exp_taylor(jnp.log(e + 1e-9) - jnp.log(s))


def squash(s, axis=-1):
    """v = (‖s‖²/(1+‖s‖²)) · s/‖s‖ (safe at 0)."""
    n2 = jnp.sum(s * s, axis=axis, keepdims=True)
    scale = n2 / (1.0 + n2) / jnp.sqrt(n2 + 1e-9)
    return s * scale


def routing_iteration(u_hat, b, *, taylor=False, update_logits=True):
    """One dynamic-routing iteration (Fig. 4 body).

    u_hat: [N, J, D] prediction vectors; b: [N, J] logits.
    Returns (v [J, D], b' [N, J], c [N, J]).
    """
    c = softmax_taylor(b, axis=1) if taylor else softmax(b, axis=1)
    s = jnp.einsum("nj,njd->jd", c, u_hat)
    v = squash(s, axis=-1)
    if update_logits:
        b = b + jnp.einsum("njd,jd->nj", u_hat, v)
    return v, b, c


def dynamic_routing(u_hat, iterations=3, *, taylor=False):
    """Full routing loop. Returns (v [J, D], c [N, J])."""
    n, j, _ = u_hat.shape
    b = jnp.zeros((n, j), dtype=u_hat.dtype)
    v = None
    c = None
    for it in range(iterations):
        v, b, c = routing_iteration(
            u_hat, b, taylor=taylor, update_logits=it + 1 < iterations
        )
    return v, c


def capsule_lengths(v, axis=-1):
    return jnp.sqrt(jnp.sum(v * v, axis=axis))


def im2col(x, k, stride):
    """[C,H,W] -> [OH*OW, C*k*k] patch matrix (the conv kernel's view)."""
    c, h, w = x.shape
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    patches = []
    for ky in range(k):
        for kx in range(k):
            sl = x[:, ky : ky + stride * oh : stride, kx : kx + stride * ow : stride]
            patches.append(sl.reshape(c, oh * ow))
    # [C, k*k, P] -> [C*k*k, P] with C-major ordering to match OIHW weights.
    stacked = jnp.stack(patches, axis=1).reshape(c * k * k, oh * ow)
    return stacked.T


def conv2d(x, w, b=None, stride=1):
    """Valid conv via im2col matmul: x [C,H,W], w [O,I,k,k] -> [O,OH,OW]."""
    o, i, k, _ = w.shape
    c, h, ww = x.shape
    assert c == i, f"channel mismatch {c} vs {i}"
    oh = (h - k) // stride + 1
    ow = (ww - k) // stride + 1
    cols = im2col(x, k, stride)  # [P, I*k*k]
    wmat = w.reshape(o, i * k * k)  # [O, I*k*k]
    out = cols @ wmat.T  # [P, O]
    if b is not None:
        out = out + b[None, :]
    return out.T.reshape(o, oh, ow)
