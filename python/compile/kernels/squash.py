"""Pallas squash kernel: row-blocked over the capsule axis.

The paper implements Squash as a dedicated unit (Fig. 11a: MAC tree,
sqrt, divider, scale multipliers); on TPU it is a row-parallel VPU op.
Rows are tiled so a block of capsules (and their D components) sits in
VMEM per grid step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _squash_kernel(x_ref, o_ref):
    x = x_ref[...]
    n2 = jnp.sum(x * x, axis=-1, keepdims=True)
    scale = n2 / (1.0 + n2) / jnp.sqrt(n2 + 1e-9)
    o_ref[...] = x * scale


@functools.partial(jax.jit, static_argnames=("block",))
def squash(x, *, block: int = 256):
    """Squash rows of `[N, D]` (one capsule per row)."""
    n, d = x.shape
    bn = pick_block(n, block)
    return pl.pallas_call(
        _squash_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x)
