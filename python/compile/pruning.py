"""Pruning methods — Python mirror of `rust/src/pruning` for the
training-side study (Table I, Fig. 5).

Kernel scores operate on OIHW numpy arrays:
  KP:    score(o,i) = Σ|W[o,i]|                       (Mao et al. [14])
  LAKP:  score(o,i) = Σ|W[o,i]| · prev[i] · next[o]   (Eq. 1 / Alg. 1)
and unstructured magnitude prunes individual weights (Han et al. [21]).
"""

import numpy as np


def kernel_abs_sums(w):
    """[O,I,kh,kw] -> [O,I] per-kernel L1."""
    return np.abs(w).sum(axis=(2, 3))


def prev_norms_from_conv(prev_w):
    """Producer magnitude per channel: whole filter of the previous layer."""
    return np.abs(prev_w).sum(axis=tuple(range(1, prev_w.ndim)))


def next_norms_from_conv(next_w):
    """Consumer magnitude per channel: all next-layer kernels reading it."""
    return np.abs(next_w).sum(axis=(0, 2, 3))


def next_norms_from_digitcaps(w_ij, pc_dim):
    """Consumers of PrimaryCaps channel k = type·pc_dim + d are the
    DigitCaps transform slices W[t, :, d, :] (shared transform layout)."""
    t, j, d_in, d_out = w_ij.shape
    # [T, d_in] magnitude -> flatten to [T*d_in].
    return np.abs(w_ij).sum(axis=(1, 3)).reshape(t * d_in)


def next_norms_from_head(head_w, out_ch):
    """Consumers for the last conv layer: the flatten-linear head's rows,
    grouped back to conv channels (head input is [C·H·W] channel-major)."""
    per_ch = head_w.shape[0] // out_ch
    return np.abs(head_w).reshape(out_ch, per_ch, -1).sum(axis=(1, 2))


def lakp_scores(w, prev, next_):
    s = kernel_abs_sums(w)
    return s * prev[None, :] * next_[:, None]


def kp_scores(w):
    return kernel_abs_sums(w)


def mask_lowest(scores, sparsity):
    """Mask (1=keep) pruning the lowest-scored fraction of kernels."""
    flat = scores.flatten()
    n_prune = int(np.floor(flat.size * sparsity))
    mask = np.ones_like(flat)
    if n_prune > 0:
        order = np.argsort(flat, kind="stable")
        mask[order[:n_prune]] = 0.0
    return mask.reshape(scores.shape)


def apply_kernel_mask(w, mask):
    """Zero pruned kernels of an OIHW tensor."""
    return w * mask[:, :, None, None]


def unstructured_mask(w, sparsity):
    flat = np.abs(w).flatten()
    n_prune = int(np.floor(flat.size * sparsity))
    mask = np.ones_like(flat)
    if n_prune > 0:
        order = np.argsort(flat, kind="stable")
        mask[order[:n_prune]] = 0.0
    return mask.reshape(w.shape)


# ---------------------------------------------------------------------------
# Model-level pruning plans
# ---------------------------------------------------------------------------

def capsnet_masks(params, sparsity, method):
    """Kernel masks for CapsNet's two prunable layers ({conv1_w, pc_w})."""
    conv1 = np.asarray(params["conv1_w"])
    pc = np.asarray(params["pc_w"])
    w_ij = np.asarray(params["w_ij"])
    if method == "lakp":
        s1 = lakp_scores(
            conv1,
            np.ones(conv1.shape[1], dtype=conv1.dtype),  # input has no producer
            next_norms_from_conv(pc),
        )
        s2 = lakp_scores(
            pc,
            prev_norms_from_conv(conv1),
            next_norms_from_digitcaps(w_ij, pc_dim=w_ij.shape[2]),
        )
    elif method == "kp":
        s1, s2 = kp_scores(conv1), kp_scores(pc)
    else:
        raise ValueError(method)
    return {
        "conv1_w": mask_lowest(s1, sparsity),
        "pc_w": mask_lowest(s2, sparsity),
    }


def convnet_masks(params, sparsity, method, head_w=None):
    """Kernel masks for every conv layer of a plain/residual conv net."""
    convs = [np.asarray(w) for w in params["convs"]]
    masks = []
    for i, w in enumerate(convs):
        if method == "kp":
            s = kp_scores(w)
        elif method == "lakp":
            prev = (
                prev_norms_from_conv(convs[i - 1])
                if i > 0
                else np.ones(w.shape[1], dtype=w.dtype)
            )
            if i + 1 < len(convs):
                nxt = next_norms_from_conv(convs[i + 1])
            elif head_w is not None:
                nxt = next_norms_from_head(np.asarray(head_w), w.shape[0])
            else:
                nxt = np.ones(w.shape[0], dtype=w.dtype)
            s = lakp_scores(w, prev, nxt)
        else:
            raise ValueError(method)
        masks.append(mask_lowest(s, sparsity))
    return masks


def capsnet_mask_fn(masks):
    """Mask re-applier for fine-tuning (jax-friendly closure)."""
    import jax.numpy as jnp

    m1 = jnp.asarray(masks["conv1_w"])[:, :, None, None]
    m2 = jnp.asarray(masks["pc_w"])[:, :, None, None]

    def fn(params):
        params = dict(params)
        params["conv1_w"] = params["conv1_w"] * m1
        params["pc_w"] = params["pc_w"] * m2
        return params

    return fn


def convnet_mask_fn(masks):
    import jax.numpy as jnp

    ms = [jnp.asarray(m)[:, :, None, None] for m in masks]

    def fn(params):
        params = dict(params)
        params["convs"] = [w * m for w, m in zip(params["convs"], ms)]
        return params

    return fn


def survived_weight_fraction_capsnet(masks, params):
    """Fraction of prunable (conv) weights surviving — Table I column."""
    total = 0
    kept = 0
    for key in ("conv1_w", "pc_w"):
        w = np.asarray(params[key])
        kk = w.shape[2] * w.shape[3]
        total += w.size
        kept += int(masks[key].sum()) * kk
    return kept / total


def survived_weight_fraction_convnet(masks, params):
    total = 0
    kept = 0
    for m, w in zip(masks, params["convs"]):
        w = np.asarray(w)
        kk = w.shape[2] * w.shape[3]
        total += w.size
        kept += int(m.sum()) * kk
    return kept / total
