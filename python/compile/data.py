"""Synthetic datasets for the training-side experiments (Table I, Fig. 5).

Procedural 10-class image tasks mirroring `rust/src/data` (DESIGN.md §4):

* ``digits``    — MNIST-like stroke digits, 28×28 grayscale.
* ``garments``  — F-MNIST-like filled silhouettes with texture, 28×28.
* ``blobs32``   — CIFAR-10-like 32×32×3 class-conditioned compositions.
* ``signs32``   — GTSRB-like 32×32×3 signs (colored shapes on noise).

NumPy-only so dataset generation never traces into JAX.
"""

import numpy as np

SIZE = 28


def _affine(points, rng, *, max_rot=0.25, smin=0.85, smax=1.1, jit=0.06):
    angle = rng.uniform(-max_rot, max_rot)
    scale = rng.uniform(smin, smax)
    dx, dy = rng.uniform(-jit, jit, size=2)
    c, s = np.cos(angle), np.sin(angle)
    p = points - 0.5
    q = np.stack(
        [0.5 + scale * (c * p[:, 0] - s * p[:, 1]) + dx,
         0.5 + scale * (s * p[:, 0] + c * p[:, 1]) + dy],
        axis=1,
    )
    return q


def _digit_points(cls):
    pi = np.pi
    t = np.linspace(0, 1, 48)

    def line(a, b):
        return np.stack([a[0] + t * (b[0] - a[0]), a[1] + t * (b[1] - a[1])], 1)

    def arc(c, r, a0, a1):
        ang = a0 + (a1 - a0) * t
        return np.stack([c[0] + r * np.cos(ang), c[1] + r * np.sin(ang)], 1)

    strokes = {
        0: [arc((0.5, 0.5), 0.32, 0, 2 * pi)],
        1: [line((0.5, 0.15), (0.5, 0.85)), line((0.38, 0.28), (0.5, 0.15))],
        2: [arc((0.5, 0.32), 0.2, pi, 2.6 * pi), line((0.66, 0.45), (0.3, 0.85)),
            line((0.3, 0.85), (0.72, 0.85))],
        3: [arc((0.48, 0.32), 0.18, 1.1 * pi, 2.5 * pi),
            arc((0.48, 0.67), 0.18, 1.5 * pi, 2.9 * pi)],
        4: [line((0.62, 0.15), (0.62, 0.85)), line((0.62, 0.15), (0.3, 0.6)),
            line((0.3, 0.6), (0.75, 0.6))],
        5: [line((0.68, 0.15), (0.35, 0.15)), line((0.35, 0.15), (0.33, 0.45)),
            arc((0.5, 0.63), 0.2, 1.2 * pi, 2.7 * pi)],
        6: [arc((0.48, 0.62), 0.2, 0, 2 * pi), arc((0.56, 0.42), 0.32, 0.9 * pi, 1.5 * pi)],
        7: [line((0.3, 0.15), (0.72, 0.15)), line((0.72, 0.15), (0.42, 0.85))],
        8: [arc((0.5, 0.32), 0.16, 0, 2 * pi), arc((0.5, 0.66), 0.19, 0, 2 * pi)],
        9: [arc((0.52, 0.38), 0.2, 0, 2 * pi), arc((0.44, 0.58), 0.32, 1.5 * pi, 2.1 * pi)],
    }
    return np.concatenate(strokes[cls % 10])


def render_digit(cls, rng):
    pts = _affine(_digit_points(cls), rng)
    sigma = rng.uniform(0.045, 0.065)
    ys, xs = np.mgrid[0:SIZE, 0:SIZE]
    cx = (xs + 0.5) / SIZE
    cy = (ys + 0.5) / SIZE
    d2 = (pts[:, None, None, 0] - cx) ** 2 + (pts[:, None, None, 1] - cy) ** 2
    img = np.exp(-d2 / (2 * sigma * sigma)).max(axis=0)
    img += rng.uniform(0, 0.04, size=img.shape)
    return np.clip(img, 0, 1).astype(np.float32)[None]  # [1,28,28]


_GARMENT_POLYS = {
    0: [(0.2, 0.25), (0.35, 0.2), (0.65, 0.2), (0.8, 0.25), (0.78, 0.4),
        (0.68, 0.38), (0.68, 0.8), (0.32, 0.8), (0.32, 0.38), (0.22, 0.4)],
    1: [(0.35, 0.15), (0.65, 0.15), (0.63, 0.85), (0.53, 0.85), (0.5, 0.45),
        (0.47, 0.85), (0.37, 0.85)],
    2: [(0.15, 0.25), (0.35, 0.18), (0.65, 0.18), (0.85, 0.25), (0.82, 0.6),
        (0.7, 0.58), (0.7, 0.82), (0.3, 0.82), (0.3, 0.58), (0.18, 0.6)],
    3: [(0.38, 0.15), (0.62, 0.15), (0.58, 0.4), (0.75, 0.85), (0.25, 0.85),
        (0.42, 0.4)],
    4: [(0.15, 0.22), (0.38, 0.15), (0.62, 0.15), (0.85, 0.22), (0.83, 0.62),
        (0.7, 0.6), (0.7, 0.88), (0.3, 0.88), (0.3, 0.6), (0.17, 0.62)],
    5: [(0.15, 0.6), (0.8, 0.55), (0.85, 0.68), (0.7, 0.72), (0.45, 0.7),
        (0.18, 0.72)],
    6: [(0.18, 0.25), (0.38, 0.18), (0.62, 0.18), (0.82, 0.25), (0.8, 0.52),
        (0.66, 0.48), (0.66, 0.85), (0.34, 0.85), (0.34, 0.48), (0.2, 0.52)],
    7: [(0.15, 0.55), (0.55, 0.5), (0.8, 0.58), (0.85, 0.7), (0.75, 0.75),
        (0.2, 0.75)],
    8: [(0.22, 0.4), (0.78, 0.4), (0.82, 0.8), (0.18, 0.8)],
    9: [(0.3, 0.3), (0.55, 0.3), (0.55, 0.55), (0.8, 0.6), (0.82, 0.75),
        (0.25, 0.75)],
}


def _point_in_poly(poly, x, y):
    c = np.zeros_like(x, dtype=bool)
    n = len(poly)
    j = n - 1
    for i in range(n):
        xi, yi = poly[i]
        xj, yj = poly[j]
        cross = ((yi > y) != (yj > y)) & (
            x < (xj - xi) * (y - yi) / (yj - yi + 1e-12) + xi
        )
        c ^= cross
        j = i
    return c


def render_garment(cls, rng):
    poly = np.array(_GARMENT_POLYS[cls % 10])
    poly = _affine(poly, rng, max_rot=0.12, smin=0.9, smax=1.08, jit=0.05)
    freq = 2.0 + (cls % 5) * 2.5
    amp = 0.15 + 0.05 * (cls % 3)
    phase = rng.uniform(0, 2 * np.pi)
    ys, xs = np.mgrid[0:SIZE, 0:SIZE]
    cx = (xs + 0.5) / SIZE
    cy = (ys + 0.5) / SIZE
    inside = _point_in_poly([tuple(p) for p in poly], cx, cy)
    tex = np.sin(freq * 2 * np.pi * cx + phase) * np.cos(freq * 2 * np.pi * cy + phase)
    img = np.where(inside, 0.75 + amp * tex, 0.0)
    img += rng.uniform(0, 0.05, size=img.shape)
    return np.clip(img, 0, 1).astype(np.float32)[None]


def render_blob32(cls, rng):
    """CIFAR-like: 2–3 colored gaussian blobs in a class-specific layout."""
    img = rng.uniform(0, 0.25, size=(3, 32, 32)).astype(np.float32)
    layouts = [(0.3, 0.3), (0.7, 0.3), (0.3, 0.7), (0.7, 0.7), (0.5, 0.5)]
    base = layouts[cls % 5]
    second = layouts[(cls // 5 + 2) % 5]
    ys, xs = np.mgrid[0:32, 0:32] / 32.0
    for (cx, cy), chan, r in [
        (base, cls % 3, 0.18),
        (second, (cls + 1) % 3, 0.12),
    ]:
        cx += rng.uniform(-0.06, 0.06)
        cy += rng.uniform(-0.06, 0.06)
        blob = np.exp(-((xs - cx) ** 2 + (ys - cy) ** 2) / (2 * r * r))
        img[chan] += blob.astype(np.float32)
    return np.clip(img, 0, 1)


def render_sign32(cls, rng):
    """GTSRB-like: a colored geometric sign (circle/triangle/square) with a
    class-dependent inner glyph on a noisy background."""
    img = rng.uniform(0.1, 0.4, size=(3, 32, 32)).astype(np.float32)
    ys, xs = np.mgrid[0:32, 0:32] / 32.0
    cx = 0.5 + rng.uniform(-0.05, 0.05)
    cy = 0.5 + rng.uniform(-0.05, 0.05)
    shape = cls % 3
    r = 0.32
    if shape == 0:
        mask = (xs - cx) ** 2 + (ys - cy) ** 2 < r * r
    elif shape == 1:
        mask = (np.abs(xs - cx) + np.abs(ys - cy)) < r
    else:
        mask = (np.abs(xs - cx) < r * 0.8) & (np.abs(ys - cy) < r * 0.8)
    ring_color = [(0.9, 0.1, 0.1), (0.1, 0.2, 0.9), (0.9, 0.8, 0.1)][cls % 3]
    for c in range(3):
        img[c] = np.where(mask, ring_color[c], img[c])
    # Inner glyph: bar angle encodes class.
    ang = (cls / 10.0) * np.pi
    gx = (xs - cx) * np.cos(ang) + (ys - cy) * np.sin(ang)
    gy = -(xs - cx) * np.sin(ang) + (ys - cy) * np.cos(ang)
    glyph = (np.abs(gx) < 0.18) & (np.abs(gy) < 0.05)
    for c in range(3):
        img[c] = np.where(glyph & mask, 0.95, img[c])
    return np.clip(img, 0, 1)


RENDERERS = {
    "digits": render_digit,
    "garments": render_garment,
    "blobs32": render_blob32,
    "signs32": render_sign32,
}


def generate(task: str, n: int, seed: int = 0):
    """Balanced dataset: returns (images [N,C,H,W] f32, labels [N] i32)."""
    rng = np.random.default_rng(seed)
    render = RENDERERS[task]
    xs, ys = [], []
    for i in range(n):
        cls = i % 10
        xs.append(render(cls, rng))
        ys.append(cls)
    return np.stack(xs), np.array(ys, dtype=np.int32)
