"""AOT bridge: lower the L2 CapsNet forward to HLO *text* for the rust
runtime (L3).

HLO text — NOT `lowered.compile().serialize()` — is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids that
the `xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts written to `artifacts/`:
  capsnet-{mnist,fmnist}-pruned.b{1,8}.hlo.txt   — pruned+optimized model
  capsnet-mnist.b1.hlo.txt                       — original (unpruned)
  manifest.json                                  — shapes + param order
  weights-{mnist,fmnist}.fcw                     — deployable weights

Weights are *parameters* of the HLO (not baked constants) so the rust
coordinator can hot-swap trained `.fcw` files without recompiling.

Usage: python -m compile.aot [--out-dir ../artifacts] [--fast]
"""

import argparse
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import CapsConfig, forward, init_params


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(cfg: CapsConfig, batch: int, *, taylor: bool = True) -> str:
    """Lower `forward(params, x)` for a fixed batch size."""

    def fn(params, x):
        lengths, v = forward(
            params, x, cfg, taylor=taylor, use_pallas=True, batch_mode="map"
        )
        return (lengths, v)

    param_spec = {
        name: jax.ShapeDtypeStruct(shape, jnp.float32)
        for name, shape in cfg.param_shapes()
    }
    x_spec = jax.ShapeDtypeStruct((batch, *cfg.input), jnp.float32)
    lowered = jax.jit(fn).lower(param_spec, x_spec)
    return to_hlo_text(lowered)


def write_fcw(path, params, cfg: CapsConfig):
    """Serialize params in the rust `.fcw` interchange format."""
    order = [name for name, _ in cfg.param_shapes()]
    with open(path, "wb") as f:
        f.write(b"FCW1")
        f.write(struct.pack("<I", len(order)))
        for name in order:
            import numpy as np

            arr = np.asarray(params[name], dtype=np.float32)
            f.write(struct.pack("<I", len(name)))
            f.write(name.encode())
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def build_all(out_dir: str, fast: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    configs = [
        (CapsConfig.paper_pruned_mnist(), [1, 8]),
        (CapsConfig.paper_pruned_fmnist(), [1, 8]),
    ]
    if not fast:
        # The original (unpruned) model, batch 1 — for end-to-end parity
        # checks against the simulator's original configuration.
        configs.append((CapsConfig.paper_full("capsnet-mnist"), [1]))
    for cfg, batches in configs:
        for b in batches:
            name = f"{cfg.name}.b{b}"
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            print(f"lowering {name} ...", flush=True)
            text = lower_model(cfg, b)
            with open(path, "w") as f:
                f.write(text)
            entries.append(
                {
                    "name": name,
                    "model": cfg.name,
                    "file": os.path.basename(path),
                    "batch": b,
                    "input_shape": [b, *cfg.input],
                    "num_classes": cfg.num_classes,
                    "dc_dim": cfg.dc_dim,
                    # jax.jit flattens the params dict in sorted-key order;
                    # the manifest records that order so the rust runtime
                    # feeds literals to the right executable parameters.
                    "params": [
                        {"name": n, "shape": list(s)}
                        for n, s in sorted(cfg.param_shapes())
                    ],
                    "outputs": ["lengths", "digit_caps"],
                }
            )
    # Deployable (random-init) weights; `make table1` overwrites with
    # trained ones.
    for cfg, tag in [
        (CapsConfig.paper_pruned_mnist(), "mnist"),
        (CapsConfig.paper_pruned_fmnist(), "fmnist"),
    ]:
        wpath = os.path.join(out_dir, f"weights-{tag}.fcw")
        if not os.path.exists(wpath):
            params = init_params(cfg, jax.random.PRNGKey(42))
            write_fcw(wpath, params, cfg)
            print(f"wrote {wpath}")
    manifest = {"version": 1, "entries": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--fast", action="store_true", help="skip the original-model HLO")
    args = ap.parse_args(argv)
    manifest = build_all(args.out_dir, fast=args.fast)
    print(f"wrote {len(manifest['entries'])} HLO artifacts to {args.out_dir}")


if __name__ == "__main__":
    sys.exit(main())
