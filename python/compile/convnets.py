"""Scaled VGG-style and ResNet-style conv nets for the Table I rows.

The paper prunes VGG-19 (CIFAR-10, GTSRB) and ResNet-18 (CIFAR-10,
GTSRB). Full-size training is out of budget on this CPU-only testbed, so
these are faithful *structural* reductions (DESIGN.md §4): VGG-small
keeps the plain stacked-3×3-conv + maxpool shape; ResNet-small keeps
identity-skip residual blocks. What Table I measures — how KP vs LAKP
degrade with sparsity — depends on the layer-to-layer coupling structure,
which both reductions preserve.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax import lax


def conv(x, w, stride=1, padding="SAME"):
    """NCHW conv with OIHW weights."""
    return lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def maxpool2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


@dataclass
class ConvNetSpec:
    """A plain conv net: list of (out_ch, stride-or-'pool') conv layers +
    a linear head. `residual` turns pairs of same-width convs into
    identity-skip blocks (ResNet-small)."""

    name: str
    in_ch: int = 3
    layers: list = field(default_factory=list)
    residual: bool = False
    num_classes: int = 10

    @staticmethod
    def vgg_small(name="vgg-small"):
        # VGG shape: stacked 3x3 convs, pool between width jumps.
        return ConvNetSpec(
            name=name,
            layers=[(16, 1), (16, "pool"), (32, 1), (32, "pool"),
                    (64, 1), (64, "pool")],
            residual=False,
        )

    @staticmethod
    def resnet_small(name="resnet-small"):
        # ResNet shape: stem + 3 residual pairs.
        return ConvNetSpec(
            name=name,
            layers=[(16, 1), (16, 1), (16, 1), (32, "pool"), (32, 1),
                    (64, "pool"), (64, 1)],
            residual=True,
        )

    def conv_shapes(self):
        """Ordered OIHW shapes of all conv layers."""
        shapes = []
        c = self.in_ch
        for out_ch, _ in self.layers:
            shapes.append((out_ch, c, 3, 3))
            c = out_ch
        return shapes


def init_params(spec: ConvNetSpec, key, input_hw=32):
    ks = jax.random.split(key, len(spec.layers) + 1)
    params = {"convs": [], "head_w": None, "head_b": None}
    c = spec.in_ch
    hw = input_hw
    for i, (out_ch, s) in enumerate(spec.layers):
        std = (2.0 / (c * 9)) ** 0.5
        params["convs"].append(std * jax.random.normal(ks[i], (out_ch, c, 3, 3)))
        c = out_ch
        if s == "pool":
            hw //= 2
    # Flatten-linear head (like VGG's FC head) — position-sensitive tasks
    # (GTSRB-like glyph angles) lose their signal under global pooling.
    feat = c * hw * hw
    params["head_w"] = (1.0 / feat) ** 0.5 * jax.random.normal(
        ks[-1], (feat, spec.num_classes)
    )
    params["head_b"] = jnp.zeros((spec.num_classes,))
    return params


def forward(params, x, spec: ConvNetSpec):
    """x: [B,C,H,W] → logits [B,num_classes]. Flattened-feature head."""
    h = x
    prev_block_input = None
    for i, ((out_ch, s), w) in enumerate(zip(spec.layers, params["convs"])):
        h_in = h
        h = conv(h, w)
        if spec.residual and prev_block_input is not None \
                and prev_block_input.shape == h.shape:
            h = h + prev_block_input  # identity skip over the pair
            prev_block_input = None
        elif spec.residual and i > 0 and h_in.shape == h.shape:
            prev_block_input = h_in
        h = jax.nn.relu(h)
        if s == "pool":
            h = maxpool2(h)
            prev_block_input = None
    feat = h.reshape(h.shape[0], -1)  # [B, C·H·W]
    return feat @ params["head_w"] + params["head_b"]


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
